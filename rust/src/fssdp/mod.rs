//! The numeric FSSDP engine: real FSSDP training of a stack of `L` MoE
//! layers across N simulated devices inside one process.
//!
//! Everything the paper's Figure 5 shows actually happens here, with real
//! numbers, once per layer per iteration:
//!
//! 1. **Sharding phase** — every layer's expert parameters + Adam states
//!    are partitioned into per-expert chunks owned by distinct devices;
//!    `--reshard-every K` re-runs Algorithm 2 jointly over all layers
//!    (unified memory space, §4.3 / Figure 8) at K-iteration boundaries.
//! 2. **Materialization phase** — each iteration, per layer, the scheduler
//!    predicts loads (sliding window, w=5), runs Algorithm 1, and executes
//!    `spAG(P, P')` on the real parameter buffers
//!    ([`crate::collectives::exec`]).
//! 3. The **gate** runs per layer on that layer's input activations
//!    (logits → softmax → Pallas top-2); the L3 **dispatcher** routes each
//!    token to a materialized replica (topology-aware, §4.4).
//! 4. **Expert compute** runs through the `expert_ffn_fwd`/`_bwd` HLO
//!    executables (Pallas kernels under PJRT), capacity-tiled. Inner
//!    layers *combine* (weight-sum the top-2 expert outputs) into the next
//!    layer's activations — the non-MoE blocks between MoE layers stay the
//!    synthetic pass-through of the seed engine. The loss sits on the last
//!    layer's per-expert outputs (bit-identical to the seed single-layer
//!    engine at `L = 1`), and the backward pass threads cotangents down
//!    the stack.
//! 5. **Gradient reduction** executes `spRS(P', P)` per layer on the real
//!    gradient buffers; shard owners apply Adam.
//!
//! The equivalence tests (`examples/fssdp_numeric`, `rust/tests/`) run the
//! same workload on 1 device (all experts local — no collectives, no
//! dispatch) and assert the final parameters match: FSSDP's placement
//! freedom does not change the math. `rust/tests/spmd_equivalence.rs`
//! additionally locks `L = 1` to the seed engine's exact bit pattern and
//! `L = 3` across executors.
//!
//! ## Public API
//!
//! The engine is configured and driven through the [`session`] facade:
//! build a validated [`SessionConfig`] ([`config`]), enter through
//! [`Session::fresh`] or [`Session::resume`], and observe progress through
//! [`StepObserver`] hooks. [`FssdpEngine`] itself is constructed only
//! inside this module; callers reach it read-only via
//! [`Session::engine`].

pub mod adam;
pub mod compute;
pub mod config;
pub mod diverge;
pub mod session;

pub use compute::ComputeMode;
pub use config::{
    parse_compute_mode, parse_pacing, parse_pacing_scale, parse_recv_timeout, parse_transport,
    Backend, ConfigError, SessionConfig, SessionConfigBuilder,
};
pub use session::{
    PrintObserver, ResumeReport, Session, SpanCtx, StatsCollector, StepObserver,
};

use std::collections::BTreeMap;
use std::time::{Duration, Instant};

use crate::checkpoint::{self, ExpertState, LayerCkpt, ReshardPlan, TrainState};
use crate::collectives::exec::{run_spag_traced, run_sprs_traced, BufferPool, ClusterMem};
use crate::collectives::sparse::{build_spag, build_sprs, SparsePlan};
use crate::dispatch::dispatch;
use crate::loadsim::LoadPredictor;
use crate::materialize::{sparse_materialize, MatConstraints};
use crate::metrics::Metrics;
use crate::placement::Placement;
use crate::runtime::Runtime;
use crate::sharding::{self, ShardingPlan};
use crate::spmd::comm::Pacing;
use crate::telemetry::Phase as TracePhase;
use crate::topology::{DeviceId, Topology};
use crate::util::rng::Rng;

use adam::{AdamCfg, AdamState};
use compute::{Compute, ExpertParams, FfnGrads, KernelScratch};

/// How the engine executes an iteration span: the sequential oracle (one
/// thread steps every simulated device in turn) or the SPMD runtime
/// ([`crate::spmd`] — one OS thread per rank over an in-process
/// communicator, with overlapped sparse collectives).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Executor {
    /// Single-threaded reference execution ([`FssdpEngine::step`]).
    Sequential,
    /// One OS thread per rank. `threads` must equal the topology's device
    /// count (SPMD = the program *is* the rank). `overlap` enables the
    /// re-materialization overlap scheduler, including the §4.3
    /// cross-layer pipeline (issue layer `l+1`'s spAG while layer `l`
    /// computes; finish layer `l+1`'s spRS while layer `l`'s backward
    /// runs); results are bit-identical either way.
    Spmd { threads: usize, overlap: bool },
}

impl Executor {
    /// The SPMD executor sized for `topo` (one thread per device,
    /// overlap scheduler on).
    pub fn spmd_for(topo: &Topology) -> Executor {
        Executor::Spmd { threads: topo.num_devices(), overlap: true }
    }
}

/// Static dimensions of one MoE layer (from the artifact manifest, or
/// chosen explicitly for the hermetic reference backend). All layers of a
/// stack share one shape.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct LayerDims {
    pub tokens: usize,
    pub d_model: usize,
    pub d_ffn: usize,
    pub experts: usize,
    pub cap: usize,
}

impl LayerDims {
    /// Floats in one expert's packed chunk: w1 ++ b1 ++ w2 ++ b2.
    pub fn chunk_len(&self) -> usize {
        self.d_model * self.d_ffn + self.d_ffn + self.d_ffn * self.d_model + self.d_model
    }

    fn from_runtime(rt: &Runtime) -> anyhow::Result<LayerDims> {
        let gate = rt.entry("gate_fwd")?;
        let ffn = rt.entry("expert_ffn_fwd")?;
        Ok(LayerDims {
            tokens: gate.extra_usize("tokens").unwrap_or(gate.inputs[0].shape[0]),
            d_model: gate.extra_usize("d_model").unwrap_or(gate.inputs[0].shape[1]),
            d_ffn: ffn.extra_usize("d_ffn").unwrap_or(ffn.inputs[1].shape[1]),
            experts: gate.inputs[1].shape[1],
            cap: ffn.extra_usize("cap").unwrap_or(ffn.inputs[0].shape[0]),
        })
    }
}

/// Split a packed chunk into borrowed `(w1, b1, w2, b2)` views — a pure
/// view-splitter over the chunk slice: the kernels read the chunk storage
/// directly, no copies.
fn unpack_chunk<'a>(dims: &LayerDims, chunk: &'a [f32]) -> ExpertParams<'a> {
    let (dm, dff) = (dims.d_model, dims.d_ffn);
    debug_assert_eq!(chunk.len(), dims.chunk_len(), "chunk length");
    let (w1, rest) = chunk.split_at(dm * dff);
    let (b1, rest) = rest.split_at(dff);
    let (w2, b2) = rest.split_at(dff * dm);
    ExpertParams { w1, b1, w2, b2 }
}

/// Accumulate `(gw1, gb1, gw2, gb2)` slices into a packed gradient chunk
/// (same element order as the packed layout).
fn accumulate_grad_parts(acc: &mut [f32], parts: &[&[f32]]) -> anyhow::Result<()> {
    let mut off = 0;
    for part in parts {
        for (a, &g) in acc[off..off + part.len()].iter_mut().zip(part.iter()) {
            *a += g;
        }
        off += part.len();
    }
    anyhow::ensure!(off == acc.len(), "grad pack length mismatch");
    Ok(())
}

/// Reusable per-key kernel buffers: packed group input, combine/cotangent
/// staging, forward output, and the five backward gradient parts, plus the
/// kernel-internal [`KernelScratch`]. One per execution context (the
/// engine's [`StepWorkspace`], each SPMD rank, each worker thread).
#[derive(Debug, Default)]
pub(crate) struct KeyScratch {
    xin: Vec<f32>,
    gy: Vec<f32>,
    y: Vec<f32>,
    gx: Vec<f32>,
    gw1: Vec<f32>,
    gb1: Vec<f32>,
    gw2: Vec<f32>,
    gb2: Vec<f32>,
    pub(crate) kernel: KernelScratch,
}

impl KeyScratch {
    fn ensure(&mut self, dims: &LayerDims) {
        let (cap, dm, dff) = (dims.cap, dims.d_model, dims.d_ffn);
        for buf in [&mut self.xin, &mut self.gy, &mut self.y, &mut self.gx] {
            if buf.len() != cap * dm {
                buf.resize(cap * dm, 0.0);
            }
        }
        if self.gw1.len() != dm * dff {
            self.gw1.resize(dm * dff, 0.0);
        }
        if self.gb1.len() != dff {
            self.gb1.resize(dff, 0.0);
        }
        if self.gw2.len() != dff * dm {
            self.gw2.resize(dff * dm, 0.0);
        }
        if self.gb2.len() != dm {
            self.gb2.resize(dm, 0.0);
        }
    }
}

/// Workspace allocation counters (see [`FssdpEngine::workspace_stats`]):
/// after warmup, `pool_allocated` stays flat while `pool_reused` grows —
/// the steady-state iteration allocates nothing.
#[derive(Debug, Clone, Copy, Default)]
pub struct WorkspaceStats {
    /// Fresh heap allocations the workspace pool served.
    pub pool_allocated: u64,
    /// Requests served from the free list.
    pub pool_reused: u64,
}

/// Per-phase wall-clock of the sequential engine's steps, accumulated
/// until [`FssdpEngine::take_phases`] drains it (the `hecate bench step`
/// JSON artifact is built from this).
#[derive(Debug, Clone, Copy, Default)]
pub struct StepPhases {
    /// spAG execution (Algorithm 1's materialization traffic).
    pub materialize: Duration,
    /// Gate forward across sources (incl. the decisions' bookkeeping).
    pub gate: Duration,
    /// Expert forward sweeps — includes the fused last-layer fwd+loss+bwd.
    pub expert_fwd: Duration,
    /// Inner-layer backward sweeps.
    pub expert_bwd: Duration,
    /// spRS execution.
    pub sprs: Duration,
    /// Adam updates + replica release.
    pub adam: Duration,
    /// Steps accumulated.
    pub steps: u64,
}

impl StepPhases {
    /// Sum of all phase durations.
    pub fn total(&self) -> Duration {
        self.materialize + self.gate + self.expert_fwd + self.expert_bwd + self.sprs + self.adam
    }
}

/// The engine's reusable per-span scratch: every buffer a training
/// iteration needs — activation/cotangent buffers per layer, gate output
/// staging, per-key kernel scratch, and the chunk-length [`BufferPool`]
/// the gradient stores and collective staging copies cycle through.
/// Allocated lazily on first use and reused across iterations, layers, and
/// spans; never part of the training state (checkpoints ignore it).
#[derive(Debug, Default)]
pub(crate) struct StepWorkspace {
    pub(crate) pool: BufferPool,
    key: KeyScratch,
    /// Per-key cotangent/combine rows staging (toks order).
    rows: Vec<f32>,
    /// `acts_stack[l][source]` — layer `l`'s input activations.
    acts_stack: Vec<Vec<Vec<f32>>>,
    /// Cotangent of the current layer's input (backward sweep).
    g: Vec<Vec<f32>>,
    /// Cotangent being assembled for the layer below.
    g_prev: Vec<Vec<f32>>,
    /// Per-source gate outputs (top-2 weights / expert indices).
    gate_w_out: Vec<Vec<f32>>,
    gate_idx: Vec<Vec<i32>>,
}

fn resize_bufs(v: &mut Vec<Vec<f32>>, count: usize, len: usize) {
    v.resize_with(count, Vec::new);
    for b in v.iter_mut() {
        if b.len() != len {
            b.resize(len, 0.0);
        }
    }
}

impl StepWorkspace {
    fn ensure_shape(&mut self, nl: usize, sources: usize, dims: &LayerDims) {
        let n = dims.tokens * dims.d_model;
        if self.acts_stack.len() != nl {
            self.acts_stack.resize_with(nl, Vec::new);
        }
        for layer in &mut self.acts_stack {
            resize_bufs(layer, sources, n);
        }
        resize_bufs(&mut self.g, sources, n);
        resize_bufs(&mut self.g_prev, sources, n);
        self.gate_w_out.resize_with(sources, Vec::new);
        self.gate_idx.resize_with(sources, Vec::new);
    }
}

/// Zero right-sized activation/cotangent buffers in place.
fn zero_bufs(bufs: &mut [Vec<f32>]) {
    for b in bufs {
        b.fill(0.0);
    }
}

/// Recycle every buffer of a gradient `ClusterMem` into the pool
/// (iteration teardown — the next iteration re-takes them zeroed).
fn drain_cluster_into_pool(mem: &mut ClusterMem, pool: &mut BufferPool) {
    for store in &mut mem.devices {
        store.retain_chunks(|_| false, pool);
    }
}

/// Generate one logical data shard's token batch for iteration `iter`
/// (deterministic in (iter, source) only — the FSSDP run, the 1-device
/// reference, and every SPMD rank regenerate identical data locally, so
/// layer-0 token payloads never need to cross the wire).
pub(crate) fn batch_for(dims: &LayerDims, iter: u64, source: usize) -> Vec<f32> {
    let mut out = Vec::new();
    batch_into(dims, iter, source, &mut out);
    out
}

/// [`batch_for`] into a reused buffer (same values, no allocation once the
/// buffer's capacity is warm).
pub(crate) fn batch_into(dims: &LayerDims, iter: u64, source: usize, out: &mut Vec<f32>) {
    let mut r = Rng::new(0xDA7A ^ (iter.wrapping_mul(0x9E3779B97F4A7C15)) ^ (source as u64) << 32);
    // drift the token distribution over iterations so expert loads
    // fluctuate (the Figure 3 dynamic the predictor must track)
    let phase = iter as f64 * 0.05;
    out.clear();
    out.reserve(dims.tokens * dims.d_model);
    for i in 0..dims.tokens * dims.d_model {
        let base = r.normal() as f32;
        let drift = ((i % dims.d_model) as f64 * 0.1 + phase).sin() as f32;
        out.push(base + 0.8 * drift);
    }
}

/// The deterministic control-plane decisions of one layer's iteration:
/// predicted placement (Algorithm 1) and the two compiled sparse
/// collectives. Every SPMD rank computes this redundantly from replicated
/// state and gets the same plan — the SPMD determinism contract (see
/// DESIGN.md) hinges on it.
#[derive(Debug, Clone)]
pub(crate) struct IterPlan {
    pub placement: Placement,
    pub spag: SparsePlan,
    pub sprs: SparsePlan,
}

pub(crate) fn build_iter_plan(
    topo: &Topology,
    shards: &Placement,
    predicted: &[f64],
    cons: MatConstraints,
) -> anyhow::Result<IterPlan> {
    let placement = sparse_materialize(topo, shards, predicted, cons);
    let spag = build_spag(topo, shards, &placement)?;
    let sprs = build_sprs(topo, &placement, shards)?;
    Ok(IterPlan { placement, spag, sprs })
}

/// Realized load fractions from the gathered gate decisions (feeds the
/// layer's predictor for the next iteration).
pub(crate) fn realized_loads(experts: usize, gate_idx: &[Vec<i32>]) -> Vec<f64> {
    let mut load_counts = vec![0usize; experts];
    for idx in gate_idx {
        for &e in idx {
            load_counts[e as usize] += 1;
        }
    }
    let total: usize = load_counts.iter().sum();
    load_counts.iter().map(|&c| c as f64 / total.max(1) as f64).collect()
}

/// `assignments[src_device][expert]` — sources map round-robin onto
/// devices (all on device 0 in the 1-device reference).
pub(crate) fn assignment_matrix(
    nd: usize,
    experts: usize,
    gate_idx: &[Vec<i32>],
) -> Vec<Vec<usize>> {
    let mut asg = vec![vec![0usize; experts]; nd];
    for (s, idx) in gate_idx.iter().enumerate() {
        let dev = s % nd;
        for &e in idx {
            asg[dev][e as usize] += 1;
        }
    }
    asg
}

/// Physical token routing: per `(dst_device, expert)` → list of
/// `(source, token_row, gate_weight)`. Routing must follow the dispatch
/// plan: we re-derive each token's destination with the same rule
/// (local → same-node → any; round-robin among candidates). Deterministic
/// in its inputs, so SPMD ranks compute it redundantly and agree.
pub(crate) type Routes = BTreeMap<(usize, usize), Vec<(usize, usize, f32)>>;

pub(crate) fn routes_from_gates(
    topo: &Topology,
    placement: &Placement,
    nd: usize,
    experts: usize,
    gate_idx: &[Vec<i32>],
    gate_w_out: &[Vec<f32>],
) -> Routes {
    let mut routes: Routes = BTreeMap::new();
    let mut cursors = vec![0usize; experts];
    for (s, idx) in gate_idx.iter().enumerate() {
        let src = DeviceId(s % nd);
        for (t, pair) in idx.chunks(2).enumerate() {
            for (slot, &e) in pair.iter().enumerate() {
                let e = e as usize;
                let w = gate_w_out[s][t * 2 + slot];
                let dst = if placement.contains(e, src) {
                    src
                } else {
                    let local = placement.holders_on_node(topo, e, topo.node_of(src));
                    let cands: Vec<DeviceId> = if local.is_empty() {
                        placement.holders(e).collect()
                    } else {
                        local
                    };
                    let d = cands[cursors[e] % cands.len()];
                    cursors[e] += 1;
                    d
                };
                routes.entry((dst.0, e)).or_default().push((s, t, w));
            }
        }
    }
    routes
}

/// Zero activation (or cotangent) buffers: one `tokens × d_model` row-major
/// buffer per source.
pub(crate) fn zero_acts(sources: usize, dims: &LayerDims) -> Vec<Vec<f32>> {
    vec![vec![0.0f32; dims.tokens * dims.d_model]; sources]
}

/// Scatter per-token rows back into per-source buffers:
/// `acc[s][t·dm + c] += rows[i·dm + c]` for the i-th routed token `(s, t)`.
/// Iteration order (toks order, then column) is part of the bit-exactness
/// contract — the sequential engine and every SPMD rank apply the same
/// rows in the same order.
pub(crate) fn scatter_rows(
    dims: &LayerDims,
    toks: &[(usize, usize, f32)],
    rows: &[f32],
    acc: &mut [Vec<f32>],
) {
    let dm = dims.d_model;
    for (i, &(s, t, _w)) in toks.iter().enumerate() {
        let dst = &mut acc[s][t * dm..(t + 1) * dm];
        for (a, &r) in dst.iter_mut().zip(rows[i * dm..(i + 1) * dm].iter()) {
            *a += r;
        }
    }
}

/// Pack the routed token rows of one capacity group into a zero-padded
/// `cap × d_model` kernel input (caller-provided buffer, fully
/// overwritten).
fn pack_group_input(
    dims: &LayerDims,
    group: &[(usize, usize, f32)],
    acts: &[Vec<f32>],
    xin: &mut [f32],
) {
    xin.fill(0.0);
    for (row, &(s, t, _w)) in group.iter().enumerate() {
        let src = &acts[s][t * dims.d_model..(t + 1) * dims.d_model];
        xin[row * dims.d_model..(row + 1) * dims.d_model].copy_from_slice(src);
    }
}

/// Expert forward + combine + loss + backward for every token routed to
/// one `(device, expert)` pair of the **last** layer, accumulating
/// parameter gradients into `acc` (capacity-tiled, group order — the
/// accumulation order is part of the bit-exactness contract between
/// executors). Returns the loss contribution and the input cotangent rows
/// (`toks.len() × d_model`, in toks order) for the layer below.
///
/// This is the seed engine's fused single-layer step body, verbatim —
/// `L = 1` bit-identity hangs on it (locked by the module test
/// `l1_step_matches_seed_oracle_bitwise`). `want_gx` gates the cotangent
/// extraction: single-layer runs have no layer below, so they skip the
/// per-group `gx` copy entirely (`rows_out` is then left empty).
///
/// Zero-copy: the chunk is read through borrowed views, all intermediates
/// live in `scr`, and the cotangent rows land in the reused `rows_out`.
#[allow(clippy::too_many_arguments)]
pub(crate) fn compute_expert_key(
    compute: &mut Compute,
    dims: &LayerDims,
    chunk: &[f32],
    toks: &[(usize, usize, f32)],
    acts: &[Vec<f32>],
    inv_t: f32,
    acc: &mut [f32],
    want_gx: bool,
    scr: &mut KeyScratch,
    rows_out: &mut Vec<f32>,
) -> anyhow::Result<f64> {
    let p = unpack_chunk(dims, chunk);
    scr.ensure(dims);
    rows_out.clear();
    if want_gx {
        rows_out.reserve(toks.len() * dims.d_model);
    }
    let (cap, dm, dff) = (dims.cap, dims.d_model, dims.d_ffn);
    let mut loss = 0.0f64;
    for group in toks.chunks(cap) {
        pack_group_input(dims, group, acts, &mut scr.xin);
        compute.ffn_fwd_into(&p, &scr.xin, cap, dm, dff, &mut scr.kernel, &mut scr.y)?;
        // combine + loss + cotangent: target 0 ⇒ L = ½‖w·y‖²/T,
        // gy_row = w²·y·(1/T) (chain through the combine weight)
        scr.gy.fill(0.0);
        for (row, &(_s, _t, w)) in group.iter().enumerate() {
            for c in 0..dm {
                let o = w * scr.y[row * dm + c];
                loss += 0.5 * (o as f64) * (o as f64) * inv_t as f64;
                scr.gy[row * dm + c] = w * o * inv_t;
            }
        }
        compute.ffn_bwd_into(
            &p,
            &scr.xin,
            &scr.gy,
            cap,
            dm,
            dff,
            &mut scr.kernel,
            FfnGrads {
                gx: &mut scr.gx,
                gw1: &mut scr.gw1,
                gb1: &mut scr.gb1,
                gw2: &mut scr.gw2,
                gb2: &mut scr.gb2,
            },
        )?;
        // gx feeds the layer below (the gate itself stays frozen;
        // single-layer runs discard it unsampled)
        if want_gx {
            rows_out.extend_from_slice(&scr.gx[..group.len() * dm]);
        }
        accumulate_grad_parts(
            acc,
            &[scr.gw1.as_slice(), scr.gb1.as_slice(), scr.gw2.as_slice(), scr.gb2.as_slice()],
        )?;
    }
    Ok(loss)
}

/// Expert forward for one `(device, expert)` key of an **inner** layer:
/// writes the combine contributions `w·y` per routed token into `rows_out`
/// (`toks.len() × d_model`, in toks order). The caller scatters them into
/// the next layer's activations ([`scatter_rows`]).
pub(crate) fn forward_expert_rows(
    compute: &mut Compute,
    dims: &LayerDims,
    chunk: &[f32],
    toks: &[(usize, usize, f32)],
    acts: &[Vec<f32>],
    scr: &mut KeyScratch,
    rows_out: &mut Vec<f32>,
) -> anyhow::Result<()> {
    let p = unpack_chunk(dims, chunk);
    scr.ensure(dims);
    rows_out.clear();
    rows_out.reserve(toks.len() * dims.d_model);
    let (cap, dm, dff) = (dims.cap, dims.d_model, dims.d_ffn);
    for group in toks.chunks(cap) {
        pack_group_input(dims, group, acts, &mut scr.xin);
        compute.ffn_fwd_into(&p, &scr.xin, cap, dm, dff, &mut scr.kernel, &mut scr.y)?;
        for (row, &(_s, _t, w)) in group.iter().enumerate() {
            for c in 0..dm {
                rows_out.push(w * scr.y[row * dm + c]);
            }
        }
    }
    Ok(())
}

/// Expert backward for one `(device, expert)` key of an **inner** layer:
/// the cotangent of this layer's combine output is `g` (per source), so
/// each routed token's expert-output cotangent is `w · g[s][t]`. Re-packs
/// the forward input from `acts` (activations are kept, intermediates are
/// recomputed by the kernel), accumulates parameter gradients into `acc`,
/// and writes the input cotangent rows for the layer below into
/// `rows_out`.
#[allow(clippy::too_many_arguments)]
pub(crate) fn backward_expert_key(
    compute: &mut Compute,
    dims: &LayerDims,
    chunk: &[f32],
    toks: &[(usize, usize, f32)],
    acts: &[Vec<f32>],
    g: &[Vec<f32>],
    acc: &mut [f32],
    scr: &mut KeyScratch,
    rows_out: &mut Vec<f32>,
) -> anyhow::Result<()> {
    let p = unpack_chunk(dims, chunk);
    scr.ensure(dims);
    rows_out.clear();
    rows_out.reserve(toks.len() * dims.d_model);
    let (cap, dm, dff) = (dims.cap, dims.d_model, dims.d_ffn);
    for group in toks.chunks(cap) {
        pack_group_input(dims, group, acts, &mut scr.xin);
        scr.gy.fill(0.0);
        for (row, &(s, t, w)) in group.iter().enumerate() {
            let gsrc = &g[s][t * dm..(t + 1) * dm];
            for (c, &gv) in gsrc.iter().enumerate() {
                scr.gy[row * dm + c] = w * gv;
            }
        }
        compute.ffn_bwd_into(
            &p,
            &scr.xin,
            &scr.gy,
            cap,
            dm,
            dff,
            &mut scr.kernel,
            FfnGrads {
                gx: &mut scr.gx,
                gw1: &mut scr.gw1,
                gb1: &mut scr.gb1,
                gw2: &mut scr.gw2,
                gb2: &mut scr.gb2,
            },
        )?;
        rows_out.extend_from_slice(&scr.gx[..group.len() * dm]);
        accumulate_grad_parts(
            acc,
            &[scr.gw1.as_slice(), scr.gb1.as_slice(), scr.gw2.as_slice(), scr.gb2.as_slice()],
        )?;
    }
    Ok(())
}

/// One expert key's outputs from a worker thread, merged on the main
/// thread in deterministic route order.
pub(crate) struct KeyOut {
    pub(crate) loss: f64,
    pub(crate) grad: Vec<f32>,
    pub(crate) rows: Vec<f32>,
}

pub(crate) type KeyOuts = Vec<((usize, usize), KeyOut)>;

/// What the workers of [`expert_keys_threaded`] compute per route key.
#[derive(Clone, Copy)]
pub(crate) enum KeyMode<'a> {
    /// Last layer: fused fwd + loss + bwd ([`compute_expert_key`]).
    FusedLast { inv_t: f32, want_gx: bool },
    /// Inner-layer forward ([`forward_expert_rows`]).
    Forward,
    /// Inner-layer backward ([`backward_expert_key`]); `g` is the combine
    /// output's cotangent per source.
    Backward { g: &'a [Vec<f32>] },
}

/// Split one layer's route keys across scoped worker threads (hermetic
/// backends only — each worker owns a stateless kernel set of the
/// requested [`ComputeMode`] and its own scratch). Outputs come back **in
/// route order** and the caller merges them in that order, so every
/// floating-point operation lands exactly where the single-threaded loop
/// would put it:
///
/// * keys are independent (one gradient buffer per `(device, expert)`
///   key), so per-key work parallelizes freely;
/// * each key's gradient accumulates into a zeroed per-key buffer in
///   capacity-group order — the identical add sequence the in-place loop
///   performs — and is installed verbatim into the zeroed gradient store;
/// * loss sums and cotangent scatters happen on the main thread in route
///   order.
///
/// In Reference mode this makes the split bit-identical to the in-line
/// loop at any thread count (locked by the module test
/// `threaded_expert_loop_is_bit_identical`); in Fast mode per-key results
/// are themselves deterministic, so the merged outcome is deterministic at
/// any thread count too. Shared by the sequential engine and each SPMD
/// rank's capacity-group loop.
pub(crate) fn expert_keys_threaded(
    threads: usize,
    kernel_mode: ComputeMode,
    dims: &LayerDims,
    params: &ClusterMem,
    routes: &Routes,
    acts: &[Vec<f32>],
    mode: KeyMode<'_>,
) -> anyhow::Result<KeyOuts> {
    let keys: Vec<(usize, usize)> = routes.keys().copied().collect();
    if keys.is_empty() {
        return Ok(Vec::new());
    }
    let nt = threads.min(keys.len()).max(1);
    let per = (keys.len() + nt - 1) / nt;
    let chunk_len = dims.chunk_len();
    let results: Vec<anyhow::Result<KeyOuts>> = std::thread::scope(|sc| {
        let handles: Vec<_> = keys
            .chunks(per)
            .map(|slice| {
                sc.spawn(move || -> anyhow::Result<KeyOuts> {
                    let mut compute = Compute::for_mode(kernel_mode);
                    let mut scr = KeyScratch::default();
                    let mut outs: KeyOuts = Vec::with_capacity(slice.len());
                    for &(dev, e) in slice {
                        let toks = routes.get(&(dev, e)).expect("key from this map");
                        let chunk = params
                            .dev(DeviceId(dev))
                            .get(e)
                            .ok_or_else(|| anyhow::anyhow!("device {dev} lacks expert {e}"))?;
                        let mut rows = Vec::new();
                        let (loss, grad) = match mode {
                            KeyMode::FusedLast { inv_t, want_gx } => {
                                let mut acc = vec![0.0f32; chunk_len];
                                let lo = compute_expert_key(
                                    &mut compute,
                                    dims,
                                    chunk,
                                    toks,
                                    acts,
                                    inv_t,
                                    &mut acc,
                                    want_gx,
                                    &mut scr,
                                    &mut rows,
                                )?;
                                (lo, acc)
                            }
                            KeyMode::Forward => {
                                forward_expert_rows(
                                    &mut compute,
                                    dims,
                                    chunk,
                                    toks,
                                    acts,
                                    &mut scr,
                                    &mut rows,
                                )?;
                                (0.0, Vec::new())
                            }
                            KeyMode::Backward { g } => {
                                let mut acc = vec![0.0f32; chunk_len];
                                backward_expert_key(
                                    &mut compute,
                                    dims,
                                    chunk,
                                    toks,
                                    acts,
                                    g,
                                    &mut acc,
                                    &mut scr,
                                    &mut rows,
                                )?;
                                (0.0, acc)
                            }
                        };
                        outs.push(((dev, e), KeyOut { loss, grad, rows }));
                    }
                    Ok(outs)
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().expect("expert worker panicked")).collect()
    });
    let mut out: KeyOuts = Vec::with_capacity(keys.len());
    for r in results {
        out.extend(r?);
    }
    Ok(out)
}

/// Per-iteration statistics of the engine, aggregated over layers
/// (sums for counts, means for ratios — at `L = 1` identical to the seed
/// engine's single-layer stats).
#[derive(Debug, Clone, Default)]
pub struct EngineStats {
    pub loss: f64,
    /// Mean λ of the layers' spAGs this iteration.
    pub spag_sparsity: f64,
    /// Materialized (chunk, device) pairs beyond the shards, all layers.
    pub replicas: usize,
    /// Tokens that crossed devices, all layers.
    pub remote_tokens: usize,
    /// Mean straggler factor of per-device expert tokens over layers.
    pub straggler: f64,
    /// Fresh workspace-pool allocations during this iteration (0 in steady
    /// state once the pool is warm; sequential executor only — the SPMD
    /// ranks report theirs through `spmd.ws_allocs` in the span metrics).
    pub ws_allocs: u64,
}

/// Everything one MoE layer owns: its shard partition, parameter chunks,
/// optimizer states, gate weights, and load predictor.
pub(crate) struct LayerState {
    /// Expert parameter chunks, placed per `shards` (plus transient
    /// replicas mid-iteration).
    pub(crate) params: ClusterMem,
    pub(crate) shards: Placement,
    /// Adam state on shard owners only (the single global copy).
    pub(crate) opt: BTreeMap<usize, AdamState>,
    /// Gate weights, replicated on every device (dense DP part; frozen in
    /// the engine — the gate's drift is exogenous, from the data stream).
    pub(crate) gate_w: Vec<f32>,
    pub(crate) predictor: LoadPredictor,
}

/// The engine itself. Constructed only through the [`Session`] facade (or
/// crate-internally); the tuning fields below are crate-private and set
/// from a validated [`SessionConfig`].
pub struct FssdpEngine {
    pub topo: Topology,
    pub dims: LayerDims,
    /// Which executor [`FssdpEngine::run_span`] uses.
    pub(crate) executor: Executor,
    pub(crate) compute: Compute,
    /// Engine construction seed (recorded in checkpoints).
    seed: u64,
    /// The MoE layer stack, bottom (layer 0) to top.
    pub(crate) layers: Vec<LayerState>,
    pub(crate) adam: AdamCfg,
    /// Memory headroom per device for Algorithm 1, in expert slots.
    pub(crate) mem_slots: usize,
    /// Overlap degree for Algorithms 1 and 2.
    pub(crate) overlap_degree: usize,
    /// Re-run Algorithm 2 (jointly over all layers) every K iterations
    /// inside [`FssdpEngine::run_span`] (0 = never) — the executed
    /// Figure 15b sweep.
    pub(crate) reshard_every: usize,
    /// Cumulative experts moved by in-run re-shards.
    pub(crate) reshards_moved: usize,
    /// `(boundary_step, moved)` per in-run re-shard of the current span
    /// (drained by [`Session`] to fire [`StepObserver::on_reshard`]).
    pub(crate) reshard_events: Vec<(u64, usize)>,
    /// Optional α–β link pacing for the SPMD communicator: transfers then
    /// occupy wall-clock time proportional to the modeled link, so the
    /// overlap scheduler's wins are physically measurable. Never affects
    /// numerics (pacing delays delivery, it cannot reorder the per-buffer
    /// accumulation orders).
    pub(crate) pacing: Option<Pacing>,
    /// Which transport backend SPMD spans run over: the in-process mpsc
    /// fabric (default) or localhost sockets, one OS process' worth of
    /// rank threads speaking the wire codec end to end.
    pub(crate) transport: crate::spmd::transport::TransportKind,
    /// Receive timeout for the socket transport (None = backend default).
    pub(crate) recv_timeout: Option<std::time::Duration>,
    /// Worker threads for the expert-kernel loops (hermetic backends
    /// only; 1 = in-line). The sequential executor fans its per-key loop
    /// out across this many scoped threads; under SPMD every rank runs
    /// its own pool of this size over its capacity groups. Reference mode
    /// stays bit-identical at any value; Fast mode is deterministic per
    /// thread count.
    pub(crate) compute_threads: usize,
    /// Reusable per-span scratch (never part of the training state).
    pub(crate) workspace: StepWorkspace,
    /// Accumulated per-phase timings of sequential steps.
    pub(crate) phases: StepPhases,
    rng: Rng,
    /// Per-rank metrics merged after the last SPMD span (None before the
    /// first parallel run).
    pub(crate) spmd_metrics: Option<Metrics>,
    /// Telemetry recorder (rank 0 / sequential timeline). `None` when
    /// tracing is disabled — every instrumentation site is then a single
    /// branch on this option, allocating nothing.
    pub(crate) tracer: Option<crate::telemetry::TraceRecorder>,
    /// Step meter: the per-rank memory ledger + load observatory. `None`
    /// when metering is disabled — the same zero-overhead discipline as
    /// `tracer` (one `Option` branch per instrumentation site).
    pub(crate) meter: Option<crate::metrics::meter::StepMeter>,
}

impl FssdpEngine {
    /// Build an `num_layers`-deep engine on the PJRT backend: load
    /// artifacts, shard experts round-robin, init parameters
    /// deterministically from `seed`. (Crate-internal; the public entry is
    /// [`Session::fresh`].)
    pub(crate) fn new_layers(
        artifact_dir: &str,
        num_layers: usize,
        topo: Topology,
        seed: u64,
    ) -> anyhow::Result<FssdpEngine> {
        let rt = Runtime::open(artifact_dir)?;
        let dims = LayerDims::from_runtime(&rt)?;
        Ok(Self::init(Compute::Pjrt(rt), dims, num_layers, topo, seed))
    }

    /// Build an `num_layers`-deep engine on the hermetic pure-Rust
    /// reference backend (no artifacts / PJRT required) — same math,
    /// explicit dimensions.
    pub(crate) fn new_reference_layers(
        dims: LayerDims,
        num_layers: usize,
        topo: Topology,
        seed: u64,
    ) -> FssdpEngine {
        Self::init(Compute::Reference(compute::Reference), dims, num_layers, topo, seed)
    }

    fn init(
        compute: Compute,
        dims: LayerDims,
        num_layers: usize,
        topo: Topology,
        seed: u64,
    ) -> FssdpEngine {
        assert!(num_layers >= 1, "engine needs at least one MoE layer");
        let nd = topo.num_devices();
        let mut rng = Rng::new(seed);
        let gate_scale = (dims.d_model as f64).powf(-0.5);

        let mut layers = Vec::with_capacity(num_layers);
        for l in 0..num_layers {
            let shards = Placement::round_robin(dims.experts, nd);
            // deterministic init: chunk (l, e) seeded on (seed, l, e) only,
            // so the device count / placement cannot affect initial values;
            // the layer-0 formula is exactly the seed engine's (the l term
            // vanishes), which is what keeps L=1 bit-identical to it.
            let mut params = ClusterMem::new(nd);
            let mut opt = BTreeMap::new();
            for e in 0..dims.experts {
                let mut er = Rng::new(
                    seed ^ (0x9E37 + e as u64 * 0x1000193)
                        ^ (l as u64).wrapping_mul(0xD1B54A32D192ED03),
                );
                let scale = (dims.d_model as f64).powf(-0.5);
                let chunk: Vec<f32> =
                    (0..dims.chunk_len()).map(|_| (er.normal() * scale) as f32).collect();
                let owner = shards.holders(e).next().unwrap();
                params.dev_mut(owner).insert(e, chunk);
                opt.insert(e, AdamState::new(dims.chunk_len()));
            }
            // gate weights are drawn from the engine RNG stream in layer
            // order — layer 0 first, so L=1 consumes exactly the seed
            // engine's draws.
            let gate_w: Vec<f32> = (0..dims.d_model * dims.experts)
                .map(|_| (rng.normal() * gate_scale * 3.0) as f32)
                .collect();
            layers.push(LayerState {
                params,
                shards,
                opt,
                gate_w,
                predictor: LoadPredictor::new(dims.experts, 5),
            });
        }
        FssdpEngine {
            topo,
            dims,
            executor: Executor::Sequential,
            compute,
            seed,
            layers,
            adam: AdamCfg::default(),
            mem_slots: 4,
            overlap_degree: 4,
            reshard_every: 0,
            reshards_moved: 0,
            reshard_events: Vec::new(),
            pacing: None,
            transport: crate::spmd::transport::TransportKind::InProc,
            recv_timeout: None,
            compute_threads: 1,
            workspace: StepWorkspace::default(),
            phases: StepPhases::default(),
            rng,
            spmd_metrics: None,
            tracer: None,
            meter: None,
        }
    }

    /// Number of MoE layers in the stack.
    pub fn num_layers(&self) -> usize {
        self.layers.len()
    }

    /// Owner device of expert `e` in layer `l`.
    pub fn owner_at(&self, l: usize, e: usize) -> DeviceId {
        self.layers[l].shards.holders(e).next().unwrap()
    }

    /// Owner device of layer 0's expert `e` (single-layer convenience).
    pub fn owner(&self, e: usize) -> DeviceId {
        self.owner_at(0, e)
    }

    /// The current owner partition of layer `l`.
    pub fn shards_at(&self, l: usize) -> &Placement {
        &self.layers[l].shards
    }

    /// Layer 0's owner partition (single-layer convenience).
    pub fn shards(&self) -> &Placement {
        self.shards_at(0)
    }

    /// Which backend executes the kernels (`"pjrt"` / `"reference"`).
    pub fn backend(&self) -> &'static str {
        self.compute.backend_name()
    }

    /// Read back an expert's parameter chunk in layer `l` (from its owner).
    pub fn expert_chunk_at(&self, l: usize, e: usize) -> &[f32] {
        self.layers[l].params.dev(self.owner_at(l, e)).get(e).expect("owner holds its shard")
    }

    /// Layer 0's expert chunk (single-layer convenience).
    pub fn expert_chunk(&self, e: usize) -> &[f32] {
        self.expert_chunk_at(0, e)
    }

    /// Which executor [`FssdpEngine::run_span`] uses.
    pub fn executor(&self) -> Executor {
        self.executor
    }

    /// The Algorithm 2 cadence in effect (0 = never).
    pub fn reshard_every(&self) -> usize {
        self.reshard_every
    }

    /// Cumulative experts moved by in-run re-shards.
    pub fn reshards_moved(&self) -> usize {
        self.reshards_moved
    }

    /// Worker threads of the expert-kernel loops (sequential engine and
    /// per SPMD rank).
    pub fn compute_threads(&self) -> usize {
        self.compute_threads
    }

    /// The kernel tier in effect (`None` under PJRT, which brings its own
    /// kernels).
    pub fn compute_mode(&self) -> Option<ComputeMode> {
        self.compute.mode()
    }

    /// Swap the hermetic kernel tier. A no-op under PJRT — the mode knob
    /// only selects between the pure-Rust tiers.
    pub(crate) fn set_compute_mode(&mut self, mode: ComputeMode) {
        if self.compute.mode().is_some() {
            self.compute = Compute::for_mode(mode);
        }
    }

    /// Per-phase wall-clock accumulated by sequential steps since
    /// construction or the last [`FssdpEngine::take_phases`].
    pub fn phases(&self) -> StepPhases {
        self.phases
    }

    /// Drain the accumulated phase timings (bench drivers sample around a
    /// timed window).
    pub fn take_phases(&mut self) -> StepPhases {
        std::mem::take(&mut self.phases)
    }

    /// Workspace allocation counters — the steady-state zero-allocation
    /// claim, measurable.
    pub fn workspace_stats(&self) -> WorkspaceStats {
        WorkspaceStats {
            pool_allocated: self.workspace.pool.allocated,
            pool_reused: self.workspace.pool.reused,
        }
    }

    /// Run one FSSDP training iteration of the whole layer stack over
    /// `sources` logical data shards (== devices in the distributed run;
    /// all mapped to device 0 in the reference run). Returns iteration
    /// statistics. This is the sequential oracle both executors must
    /// reproduce bit-exactly.
    ///
    /// Zero-copy discipline: every tensor/chunk buffer the iteration
    /// needs comes out of the engine's reusable step workspace
    /// (activations, gate outputs, kernel scratch, gradient stores via
    /// the buffer pool), so a warm in-line (`compute_threads == 1`)
    /// iteration allocates no f32 buffers — `EngineStats::ws_allocs`
    /// measures the pool misses. Control-plane maps (plans, route tables)
    /// still allocate per iteration; they are small and off the numeric
    /// path. With `compute_threads > 1` on the reference backend the
    /// per-key expert loops run on scoped worker threads, trading per-key
    /// output buffers and thread spawns (not pool-counted) for
    /// parallelism; results merge in route order, keeping every
    /// floating-point accumulation order — and therefore every bit —
    /// identical.
    pub fn step(&mut self, iter: u64, sources: usize) -> anyhow::Result<EngineStats> {
        let nd = self.topo.num_devices();
        let dims = self.dims;
        let nl = self.layers.len();
        let cons =
            MatConstraints { overlap_degree: self.overlap_degree, mem_slots: self.mem_slots };
        let adam = self.adam;
        let threads = self.compute_threads;
        let kernel_mode = self.compute.mode();
        let use_threads = threads > 1 && kernel_mode.is_some();
        let kernel_mode = kernel_mode.unwrap_or_default();
        let mut stats = EngineStats::default();

        // All layers' plans are knowable up front: predictions use history
        // through iteration `iter - 1` only.
        let metered = self.meter.is_some();
        let mut plans = Vec::with_capacity(nl);
        let mut preds: Vec<Vec<f64>> = Vec::new();
        for ls in &self.layers {
            let pred = ls.predictor.predict();
            plans.push(build_iter_plan(&self.topo, &ls.shards, &pred, cons)?);
            if metered {
                // keep the plan-time prediction so the meter can score it
                // against the realized loads below
                preds.push(pred);
            }
        }

        // Split the engine into disjoint field borrows: the expert loops
        // read the parameter stores while the compute backend and the
        // workspace are borrowed mutably — disjoint by field.
        let FssdpEngine { topo, layers, compute, workspace: ws, phases, tracer, meter, .. } =
            self;
        let topo: &Topology = topo;
        ws.ensure_shape(nl, sources, &dims);
        let pool_allocs0 = ws.pool.allocated;

        // ---- forward sweep ----
        for s in 0..sources {
            batch_into(&dims, iter, s, &mut ws.acts_stack[0][s]);
        }
        let mut all_routes: Vec<Routes> = Vec::with_capacity(nl);
        let mut grads_stack: Vec<ClusterMem> = Vec::with_capacity(nl);
        let inv_t = 1.0f32 / (dims.tokens * sources) as f32;
        let mut loss = 0.0f64;

        for l in 0..nl {
            let last = l + 1 == nl;
            let plan = &plans[l];
            stats.spag_sparsity += plan.spag.sparsity;
            stats.replicas += plan.placement.len() - layers[l].shards.len();

            // materialization phase: Algorithm 1 plan → spAG on the buffers
            let t0 = Instant::now();
            run_spag_traced(
                &mut layers[l].params,
                &plan.spag,
                &mut ws.pool,
                tracer.as_mut(),
                iter as usize,
                l,
            )?;
            phases.materialize += t0.elapsed();
            if let Some(tr) = tracer {
                tr.span_from(TracePhase::Materialize, iter as usize, l, t0, 0);
            }
            if let Some(m) = meter {
                // memory ledger: sample right after spAG — the layer's
                // per-iteration peak (owned shards + materialized
                // replicas). The workspace pool is shared across simulated
                // devices here, so its idle bytes repeat per rank row;
                // there is no wire, so payload bytes are 0.
                let pool_idle = ws.pool.idle_bytes();
                for d in 0..nd {
                    let resident =
                        layers[l].params.dev(DeviceId(d)).resident_len() as u64 * 4;
                    m.sample_mem(iter as usize, l, d, resident, pool_idle, 0);
                }
            }

            // gate per source on this layer's input activations (borrowed
            // weights and activations, reused output buffers)
            let t0 = Instant::now();
            for s in 0..sources {
                compute.gate_fwd_into(
                    &ws.acts_stack[l][s],
                    &layers[l].gate_w,
                    dims.tokens,
                    dims.d_model,
                    dims.experts,
                    &mut ws.key.kernel,
                    &mut ws.gate_w_out[s],
                    &mut ws.gate_idx[s],
                )?;
            }
            // realized loads feed this layer's predictor for the NEXT iter
            let realized = realized_loads(dims.experts, &ws.gate_idx);
            if let Some(m) = meter {
                // load observatory: score the plan-time prediction against
                // what the gate actually produced, before the predictor
                // absorbs it
                m.sample_load(iter as usize, l, &preds[l], &realized);
            }
            layers[l].predictor.observe(&realized);
            phases.gate += t0.elapsed();
            if let Some(tr) = tracer {
                tr.span_from(TracePhase::Gate, iter as usize, l, t0, 0);
            }

            // dispatch (L3) stats
            let asg = assignment_matrix(nd, dims.experts, &ws.gate_idx);
            let dplan = dispatch(topo, &plan.placement, &asg);
            stats.remote_tokens += dplan.remote_tokens();
            stats.straggler += crate::util::stats::straggler_factor(
                &dplan.device_compute_tokens().iter().map(|&t| t as f64).collect::<Vec<_>>(),
            );

            let routes = routes_from_gates(
                topo,
                &plan.placement,
                nd,
                dims.experts,
                &ws.gate_idx,
                &ws.gate_w_out,
            );

            // grads cluster-mem mirrors the materialized placement, zeroed
            // buffers drawn from the workspace pool
            let mut grads = ClusterMem::new(nd);
            for e in 0..dims.experts {
                for d in plan.placement.holders(e) {
                    grads.dev_mut(d).insert(e, ws.pool.take_zeroed(dims.chunk_len()));
                }
            }

            let t0 = Instant::now();
            if last {
                // fused fwd + loss + bwd (the seed single-layer body);
                // gx seeds the backward sweep of the layers below
                let want_gx = nl > 1;
                if want_gx {
                    zero_bufs(&mut ws.g);
                }
                if use_threads {
                    let outs = expert_keys_threaded(
                        threads,
                        kernel_mode,
                        &dims,
                        &layers[l].params,
                        &routes,
                        &ws.acts_stack[l],
                        KeyMode::FusedLast { inv_t, want_gx },
                    )?;
                    for ((dev, e), out) in outs {
                        loss += out.loss;
                        let acc = grads
                            .dev_mut(DeviceId(dev))
                            .get_mut(e)
                            .expect("grads cover the placement");
                        acc.copy_from_slice(&out.grad);
                        if want_gx {
                            let toks = routes.get(&(dev, e)).expect("key from this map");
                            scatter_rows(&dims, toks, &out.rows, &mut ws.g);
                        }
                    }
                } else {
                    for (&(dev, e), toks) in &routes {
                        let chunk = layers[l]
                            .params
                            .dev(DeviceId(dev))
                            .get(e)
                            .ok_or_else(|| anyhow::anyhow!("device {dev} lacks expert {e}"))?;
                        let acc = grads
                            .dev_mut(DeviceId(dev))
                            .get_mut(e)
                            .expect("grads cover the placement");
                        let lo = compute_expert_key(
                            compute,
                            &dims,
                            chunk,
                            toks,
                            &ws.acts_stack[l],
                            inv_t,
                            acc,
                            want_gx,
                            &mut ws.key,
                            &mut ws.rows,
                        )?;
                        loss += lo;
                        if want_gx {
                            scatter_rows(&dims, toks, &ws.rows, &mut ws.g);
                        }
                    }
                }
            } else {
                // inner layer: forward + combine into the next layer's
                // input activations (disjoint halves of the acts stack)
                let (lo_acts, hi_acts) = ws.acts_stack.split_at_mut(l + 1);
                let acts = &lo_acts[l];
                let next = &mut hi_acts[0];
                zero_bufs(next);
                if use_threads {
                    let outs = expert_keys_threaded(
                        threads,
                        kernel_mode,
                        &dims,
                        &layers[l].params,
                        &routes,
                        acts,
                        KeyMode::Forward,
                    )?;
                    for ((dev, e), out) in outs {
                        let toks = routes.get(&(dev, e)).expect("key from this map");
                        scatter_rows(&dims, toks, &out.rows, next);
                    }
                } else {
                    for (&(dev, e), toks) in &routes {
                        let chunk = layers[l]
                            .params
                            .dev(DeviceId(dev))
                            .get(e)
                            .ok_or_else(|| anyhow::anyhow!("device {dev} lacks expert {e}"))?;
                        forward_expert_rows(
                            compute,
                            &dims,
                            chunk,
                            toks,
                            acts,
                            &mut ws.key,
                            &mut ws.rows,
                        )?;
                        scatter_rows(&dims, toks, &ws.rows, next);
                    }
                }
            }
            phases.expert_fwd += t0.elapsed();
            if let Some(tr) = tracer {
                let rows: u64 = routes.values().map(|t| t.len() as u64).sum();
                tr.span_from(TracePhase::ExpertFwd, iter as usize, l, t0, rows);
            }
            all_routes.push(routes);
            grads_stack.push(grads);
        }
        stats.loss = loss;
        stats.spag_sparsity /= nl as f64;
        stats.straggler /= nl as f64;

        // ---- backward sweep, top down: bwd compute (inner layers only;
        // the last layer's grads are complete) → spRS → Adam → release ----
        for l in (0..nl).rev() {
            if l + 1 < nl {
                let t0 = Instant::now();
                let routes = &all_routes[l];
                if l > 0 {
                    zero_bufs(&mut ws.g_prev);
                }
                if use_threads {
                    let outs = expert_keys_threaded(
                        threads,
                        kernel_mode,
                        &dims,
                        &layers[l].params,
                        routes,
                        &ws.acts_stack[l],
                        KeyMode::Backward { g: &ws.g },
                    )?;
                    for ((dev, e), out) in outs {
                        let acc = grads_stack[l]
                            .dev_mut(DeviceId(dev))
                            .get_mut(e)
                            .expect("grads cover the placement");
                        acc.copy_from_slice(&out.grad);
                        if l > 0 {
                            let toks = routes.get(&(dev, e)).expect("key from this map");
                            scatter_rows(&dims, toks, &out.rows, &mut ws.g_prev);
                        }
                    }
                } else {
                    for (&(dev, e), toks) in routes {
                        let chunk = layers[l]
                            .params
                            .dev(DeviceId(dev))
                            .get(e)
                            .ok_or_else(|| {
                                anyhow::anyhow!("device {dev} lost expert {e} before bwd")
                            })?;
                        let acc = grads_stack[l]
                            .dev_mut(DeviceId(dev))
                            .get_mut(e)
                            .expect("grads cover the placement");
                        backward_expert_key(
                            compute,
                            &dims,
                            chunk,
                            toks,
                            &ws.acts_stack[l],
                            &ws.g,
                            acc,
                            &mut ws.key,
                            &mut ws.rows,
                        )?;
                        if l > 0 {
                            scatter_rows(&dims, toks, &ws.rows, &mut ws.g_prev);
                        }
                    }
                }
                if l > 0 {
                    std::mem::swap(&mut ws.g, &mut ws.g_prev);
                }
                phases.expert_bwd += t0.elapsed();
                if let Some(tr) = tracer {
                    tr.span_from(TracePhase::ExpertBwd, iter as usize, l, t0, 0);
                }
            }

            // spRS: reduce this layer's gradients to the shard owners
            let t0 = Instant::now();
            run_sprs_traced(
                &mut grads_stack[l],
                &plans[l].sprs,
                &layers[l].shards,
                &mut ws.pool,
                tracer.as_mut(),
                iter as usize,
                l,
            )?;
            phases.sprs += t0.elapsed();
            if let Some(tr) = tracer {
                tr.span_from(TracePhase::SprsWait, iter as usize, l, t0, 0);
            }

            // optimizer step on owners; release materialized replicas
            let t0 = Instant::now();
            let layer = &mut layers[l];
            for e in 0..dims.experts {
                let owner = layer.shards.holders(e).next().expect("partition has a holder");
                let grad = grads_stack[l]
                    .dev(owner)
                    .get(e)
                    .ok_or_else(|| anyhow::anyhow!("owner of {e} lost its gradient"))?;
                let p = layer.params.dev_mut(owner).get_mut(e).expect("owner holds its shard");
                layer
                    .opt
                    .get_mut(&e)
                    .expect("every expert has optimizer state")
                    .update(&adam, p, grad);
            }
            // re-materialization: drop non-shard replicas (memory reuse,
            // §4), recycling their buffers for the next iteration
            for d in 0..nd {
                let dev = DeviceId(d);
                let shards = &layer.shards;
                layer.params.dev_mut(dev).retain_chunks(|c| shards.contains(c, dev), &mut ws.pool);
            }
            // this layer's gradient buffers go back to the pool too
            drain_cluster_into_pool(&mut grads_stack[l], &mut ws.pool);
            phases.adam += t0.elapsed();
            if let Some(tr) = tracer {
                tr.span_from(TracePhase::Adam, iter as usize, l, t0, 0);
            }
        }
        phases.steps += 1;
        stats.ws_allocs = ws.pool.allocated - pool_allocs0;
        Ok(stats)
    }

    /// Re-run Algorithm 2 jointly over all layers (sticky variant, seeded
    /// from the current partition) using each layer's predictor window, and
    /// migrate the owned chunks accordingly. Returns how many experts
    /// moved. Runs between iteration spans only, so both executors see the
    /// merged engine state — re-sharding is deterministic in (state, topo).
    pub fn reshard_now(&mut self) -> usize {
        let loads: Vec<Vec<f64>> = self.layers.iter().map(|ls| ls.predictor.predict()).collect();
        let prev = ShardingPlan {
            layers: self.layers.iter().map(|ls| ls.shards.clone()).collect(),
        };
        let plan = sharding::heterogeneous_sticky(
            &self.topo,
            &loads,
            self.overlap_degree.min(self.dims.experts),
            Some(&prev),
        );
        let mut moved = 0usize;
        for (ls, new_shards) in self.layers.iter_mut().zip(plan.layers) {
            for e in 0..self.dims.experts {
                let old_owner = ls.shards.holders(e).next().expect("partition has a holder");
                let new_owner = new_shards.holders(e).next().expect("partition has a holder");
                if old_owner != new_owner {
                    let chunk = ls
                        .params
                        .dev_mut(old_owner)
                        .remove(e)
                        .expect("old owner holds the chunk between spans");
                    ls.params.dev_mut(new_owner).insert(e, chunk);
                    moved += 1;
                }
            }
            ls.shards = new_shards;
        }
        self.reshards_moved += moved;
        moved
    }

    /// Run `iters` consecutive iterations starting at `start` on the
    /// configured [`Executor`], returning per-iteration statistics.
    ///
    /// With `reshard_every = K > 0`, the span is split at absolute-step
    /// multiples of K and [`FssdpEngine::reshard_now`] runs at each
    /// boundary — Figure 15b executed rather than modeled. Boundaries are
    /// functions of the absolute step, so span chunking (checkpoint
    /// cadence, executor) never changes where re-shards happen.
    pub fn run_span(
        &mut self,
        start: u64,
        iters: usize,
        sources: usize,
    ) -> anyhow::Result<Vec<EngineStats>> {
        self.reshard_events.clear();
        if self.reshard_every == 0 {
            return self.run_span_inner(start, iters, sources);
        }
        let k = self.reshard_every as u64;
        let end = start + iters as u64;
        let mut out = Vec::with_capacity(iters);
        let mut step = start;
        // The SPMD executor replaces `spmd_metrics` per sub-span; merge the
        // sub-spans so callers see the whole span's timers.
        let mut span_metrics: Option<Metrics> = None;
        while step < end {
            let next_boundary = (step / k + 1) * k;
            let span = (end.min(next_boundary) - step) as usize;
            out.extend(self.run_span_inner(step, span, sources)?);
            if let Some(m) = self.spmd_metrics.take() {
                match &mut span_metrics {
                    Some(acc) => acc.merge(&m),
                    None => span_metrics = Some(m),
                }
            }
            step += span as u64;
            if step % k == 0 {
                let t0 = Instant::now();
                let moved = self.reshard_now();
                if let Some(tr) = &mut self.tracer {
                    tr.span_from(TracePhase::Reshard, step as usize, 0, t0, moved as u64);
                }
                self.reshard_events.push((step, moved));
                crate::log_kv!(
                    crate::util::logging::Level::Info,
                    "reshard",
                    step = step,
                    moved = moved
                );
            }
        }
        if span_metrics.is_some() {
            // gauges (`spmd.ranks`, pool levels) take max under `merge`,
            // so sub-span aggregation needs no fix-ups
            self.spmd_metrics = span_metrics;
        }
        Ok(out)
    }

    /// One reshard-free span on the configured executor.
    ///
    /// `Executor::Sequential` loops [`FssdpEngine::step`];
    /// `Executor::Spmd` hands the whole span to the parallel runtime
    /// ([`crate::spmd::run_span`]) — one OS thread per rank, state split
    /// out per-rank at span entry and merged back at span exit, so
    /// checkpointing, [`FssdpEngine::snapshot`], and `expert_chunk` work
    /// identically under both executors.
    fn run_span_inner(
        &mut self,
        start: u64,
        iters: usize,
        sources: usize,
    ) -> anyhow::Result<Vec<EngineStats>> {
        match self.executor {
            Executor::Sequential => {
                let mut out = Vec::with_capacity(iters);
                for k in 0..iters {
                    out.push(self.step(start + k as u64, sources)?);
                }
                Ok(out)
            }
            Executor::Spmd { threads, overlap } => {
                crate::spmd::run_span(self, start, iters, sources, threads, overlap)
            }
        }
    }

    /// Per-rank metrics merged over the most recent SPMD span (None if the
    /// engine has only run sequentially).
    pub fn spmd_metrics(&self) -> Option<&Metrics> {
        self.spmd_metrics.as_ref()
    }

    /// Telemetry events recorded so far, merged across ranks (None when
    /// tracing is disabled).
    pub fn trace_events(&self) -> Option<&[crate::telemetry::Event]> {
        self.tracer.as_ref().map(|t| t.events())
    }

    /// The step meter — memory ledger + load observatory samples recorded
    /// so far, merged across ranks (None when metering is disabled).
    pub fn meter_samples(&self) -> Option<&crate::metrics::meter::StepMeter> {
        self.meter.as_ref()
    }

    /// Drain the `(boundary_step, moved)` re-shard events of the most
    /// recent [`FssdpEngine::run_span`] (the [`Session`] fires
    /// [`StepObserver::on_reshard`] from them).
    pub(crate) fn take_reshard_events(&mut self) -> Vec<(u64, usize)> {
        std::mem::take(&mut self.reshard_events)
    }

    // ---- checkpointing (the durable state is exactly the shard sets) ----

    /// Capture the complete training state at a step boundary: every
    /// layer's expert parameter chunks + Adam moments (read from their
    /// owners), gate weights and load-predictor window, plus the RNG
    /// stream and `step` (the next iteration to run). `data_shards` is the
    /// logical data-shard count of the run (`sources` at the `step` call
    /// sites) — it must survive elastic restarts unchanged.
    pub fn snapshot(&self, step: u64, data_shards: usize) -> TrainState {
        let layers: Vec<LayerCkpt> = self
            .layers
            .iter()
            .map(|ls| {
                let owners: Vec<usize> = (0..self.dims.experts)
                    .map(|e| ls.shards.holders(e).next().unwrap().0)
                    .collect();
                let experts: Vec<ExpertState> = (0..self.dims.experts)
                    .map(|e| {
                        let owner = DeviceId(owners[e]);
                        let chunk =
                            ls.params.dev(owner).get(e).expect("owner holds its shard").to_vec();
                        let o = ls.opt.get(&e).expect("every expert has optimizer state");
                        ExpertState { chunk, m: o.m.clone(), v: o.v.clone(), t: o.t }
                    })
                    .collect();
                LayerCkpt {
                    owners,
                    experts,
                    gate_w: ls.gate_w.clone(),
                    predictor_history: ls.predictor.history(),
                }
            })
            .collect();
        TrainState {
            step,
            dims: self.dims,
            seed: self.seed,
            data_shards,
            layers,
            predictor_window: self.layers[0].predictor.window(),
            rng_state: self.rng.state(),
            mem_slots: self.mem_slots,
            overlap_degree: self.overlap_degree,
            reshard_every: self.reshard_every,
        }
    }

    /// Rebuild an engine from a restored [`TrainState`] on `topo`, which
    /// may have a *different* device count than the `old_world` that wrote
    /// the checkpoint (elastic resume). Same world size reuses the saved
    /// owner layouts (bit-identical resume); a different world size re-runs
    /// the heterogeneous sharding planner jointly over the restored load
    /// windows — FSSDP placement freedom guarantees the training math is
    /// unchanged.
    pub(crate) fn resume_with(
        compute: Compute,
        topo: Topology,
        state: &TrainState,
        old_world: usize,
    ) -> anyhow::Result<(FssdpEngine, ReshardPlan)> {
        let dims = state.dims;
        anyhow::ensure!(!state.layers.is_empty(), "state holds no layers");
        let plan = checkpoint::reshard::plan(state, old_world, &topo)?;
        let nd = topo.num_devices();
        let mut layers = Vec::with_capacity(state.layers.len());
        for (l, lc) in state.layers.iter().enumerate() {
            anyhow::ensure!(
                lc.experts.len() == dims.experts,
                "layer {l} holds {} experts, dims say {}",
                lc.experts.len(),
                dims.experts
            );
            anyhow::ensure!(
                lc.gate_w.len() == dims.d_model * dims.experts,
                "layer {l}: gate_w has {} floats, dims imply {}",
                lc.gate_w.len(),
                dims.d_model * dims.experts
            );
            let shards = plan.shards[l].clone();
            let mut params = ClusterMem::new(nd);
            let mut opt = BTreeMap::new();
            for (e, st) in lc.experts.iter().enumerate() {
                anyhow::ensure!(
                    st.chunk.len() == dims.chunk_len(),
                    "layer {l} expert {e}: chunk has {} floats, dims imply {}",
                    st.chunk.len(),
                    dims.chunk_len()
                );
                let owner = shards.holders(e).next().expect("partition has a holder");
                params.dev_mut(owner).insert(e, st.chunk.clone());
                opt.insert(e, AdamState { m: st.m.clone(), v: st.v.clone(), t: st.t });
            }
            layers.push(LayerState {
                params,
                shards,
                opt,
                gate_w: lc.gate_w.clone(),
                predictor: LoadPredictor::restore(
                    dims.experts,
                    state.predictor_window,
                    lc.predictor_history.clone(),
                ),
            });
        }
        let engine = FssdpEngine {
            topo,
            dims,
            executor: Executor::Sequential,
            compute,
            seed: state.seed,
            layers,
            adam: AdamCfg::default(),
            mem_slots: state.mem_slots,
            overlap_degree: state.overlap_degree,
            reshard_every: state.reshard_every,
            reshards_moved: 0,
            reshard_events: Vec::new(),
            pacing: None,
            transport: crate::spmd::transport::TransportKind::InProc,
            recv_timeout: None,
            compute_threads: 1,
            workspace: StepWorkspace::default(),
            phases: StepPhases::default(),
            rng: Rng::from_state(state.rng_state),
            spmd_metrics: None,
            tracer: None,
            meter: None,
        };
        Ok((engine, plan))
    }

    /// [`FssdpEngine::resume_with`] on the reference backend (hermetic).
    pub(crate) fn resume_reference(
        topo: Topology,
        state: &TrainState,
        old_world: usize,
    ) -> anyhow::Result<(FssdpEngine, ReshardPlan)> {
        Self::resume_with(Compute::Reference(compute::Reference), topo, state, old_world)
    }

    /// [`FssdpEngine::resume_with`] on the PJRT backend. The artifact
    /// dimensions must match the checkpoint's.
    pub(crate) fn resume(
        artifact_dir: &str,
        topo: Topology,
        state: &TrainState,
        old_world: usize,
    ) -> anyhow::Result<(FssdpEngine, ReshardPlan)> {
        let rt = Runtime::open(artifact_dir)?;
        let dims = LayerDims::from_runtime(&rt)?;
        anyhow::ensure!(
            dims == state.dims,
            "artifact dims {dims:?} do not match checkpoint dims {:?}",
            state.dims
        );
        Self::resume_with(Compute::Pjrt(rt), topo, state, old_world)
    }
}

/// Reference-backend dimensions used when no artifacts are available
/// (small enough for CLI demos and CI).
pub fn reference_dims() -> LayerDims {
    LayerDims { tokens: 16, d_model: 8, d_ffn: 16, experts: 8, cap: 16 }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collectives::exec::{run_spag, run_sprs};
    use crate::runtime::HostTensor;
    use crate::testing::{all_chunks, max_rel_err};

    #[test]
    fn reference_engine_trains_and_matches_single_device() {
        // Hermetic version of tests/fssdp_equivalence.rs: the reference
        // backend across 4 devices equals the 1-device run on the same data.
        let sources = 4;
        let dims = reference_dims();
        let run = |topo: Topology| -> Vec<Vec<f32>> {
            let mut e = FssdpEngine::new_reference_layers(dims, 1, topo, 7);
            for i in 0..3 {
                e.step(i, sources).unwrap();
            }
            (0..e.dims.experts).map(|x| e.expert_chunk(x).to_vec()).collect()
        };
        let dist = run(Topology::cluster_a(2, 2));
        let refr = run(Topology::flat(1, 1e9));
        for (e, (d, r)) in dist.iter().zip(refr.iter()).enumerate() {
            let err = max_rel_err(d, r);
            assert!(err < 2e-3, "expert {e}: max rel err {err}");
        }
    }

    #[test]
    fn multilayer_engine_matches_single_device_reference() {
        // Placement freedom carries through the layer stack: an L=2
        // distributed run equals the all-local 1-device run on the same
        // data within the established tolerance.
        let sources = 4;
        let dims = reference_dims();
        let run = |topo: Topology| -> Vec<Vec<f32>> {
            let mut e = FssdpEngine::new_reference_layers(dims, 2, topo, 7);
            for i in 0..3 {
                e.step(i, sources).unwrap();
            }
            let mut out = Vec::new();
            for l in 0..2 {
                for x in 0..e.dims.experts {
                    out.push(e.expert_chunk_at(l, x).to_vec());
                }
            }
            out
        };
        let dist = run(Topology::cluster_a(2, 2));
        let refr = run(Topology::flat(1, 1e9));
        for (i, (d, r)) in dist.iter().zip(refr.iter()).enumerate() {
            let err = max_rel_err(d, r);
            assert!(err < 2e-3, "chunk {i}: max rel err {err}");
        }
    }

    #[test]
    fn reference_engine_loss_decreases() {
        let mut e =
            FssdpEngine::new_reference_layers(reference_dims(), 1, Topology::cluster_a(2, 2), 11);
        let first = e.step(0, 4).unwrap().loss;
        let mut last = first;
        for i in 1..6 {
            last = e.step(i, 4).unwrap().loss;
        }
        assert!(last < first, "loss {first} -> {last}");
        assert_eq!(e.backend(), "reference");
    }

    #[test]
    fn multilayer_loss_decreases_and_gradients_reach_layer0() {
        let mut e =
            FssdpEngine::new_reference_layers(reference_dims(), 3, Topology::cluster_a(2, 2), 11);
        let before: Vec<Vec<f32>> =
            (0..e.dims.experts).map(|x| e.expert_chunk_at(0, x).to_vec()).collect();
        let first = e.step(0, 4).unwrap().loss;
        let mut last = first;
        for i in 1..6 {
            last = e.step(i, 4).unwrap().loss;
        }
        assert!(last < first, "loss {first} -> {last}");
        // the backward pass must actually reach layer 0's parameters
        let after: Vec<Vec<f32>> =
            (0..e.dims.experts).map(|x| e.expert_chunk_at(0, x).to_vec()).collect();
        assert_ne!(before, after, "layer-0 parameters must move under training");
    }

    /// Transcription of the seed (pre-multi-layer) engine's `step` body,
    /// operating on layer 0 of a 1-layer engine: spAG → gate → routes →
    /// fused fwd/loss/bwd per key → spRS → Adam → release → observe. Kept
    /// as the oracle for the L=1 bit-identity lock below.
    fn seed_oracle_step(e: &mut FssdpEngine, iter: u64, sources: usize) -> f64 {
        let nd = e.topo.num_devices();
        let dims = e.dims;
        let cons = MatConstraints { overlap_degree: e.overlap_degree, mem_slots: e.mem_slots };
        let predicted = e.layers[0].predictor.predict();
        let plan = build_iter_plan(&e.topo, &e.layers[0].shards, &predicted, cons).unwrap();
        run_spag(&mut e.layers[0].params, &plan.spag).unwrap();

        let gate_wt =
            HostTensor::f32(vec![dims.d_model, dims.experts], e.layers[0].gate_w.clone());
        let mut batches: Vec<Vec<f32>> = Vec::with_capacity(sources);
        let mut gate_w_out: Vec<Vec<f32>> = Vec::with_capacity(sources);
        let mut gate_idx: Vec<Vec<i32>> = Vec::with_capacity(sources);
        for s in 0..sources {
            let x = batch_for(&dims, iter, s);
            let xt = HostTensor::f32(vec![dims.tokens, dims.d_model], x.clone());
            let out = e.compute.execute("gate_fwd", &[xt, gate_wt.clone()]).unwrap();
            gate_w_out.push(out[1].as_f32().unwrap().to_vec());
            gate_idx.push(out[2].as_i32().unwrap().to_vec());
            batches.push(x);
        }
        let realized = realized_loads(dims.experts, &gate_idx);
        let routes = routes_from_gates(
            &e.topo,
            &plan.placement,
            nd,
            dims.experts,
            &gate_idx,
            &gate_w_out,
        );
        let mut grads = ClusterMem::new(nd);
        for x in 0..dims.experts {
            for d in plan.placement.holders(x) {
                grads.dev_mut(d).insert(x, vec![0.0f32; dims.chunk_len()]);
            }
        }
        let mut loss = 0.0f64;
        let inv_t = 1.0f32 / (dims.tokens * sources) as f32;
        let mut scr = KeyScratch::default();
        let mut rows = Vec::new();
        for (&(dev, x), toks) in &routes {
            let chunk = e.layers[0].params.dev(DeviceId(dev)).get(x).unwrap().to_vec();
            let acc = grads.dev_mut(DeviceId(dev)).get_mut(x).unwrap();
            let lo = compute_expert_key(
                &mut e.compute,
                &dims,
                &chunk,
                toks,
                &batches,
                inv_t,
                acc,
                false,
                &mut scr,
                &mut rows,
            )
            .unwrap();
            loss += lo;
        }
        run_sprs(&mut grads, &plan.sprs, &e.layers[0].shards).unwrap();
        let layer = &mut e.layers[0];
        for x in 0..dims.experts {
            let owner = layer.shards.holders(x).next().unwrap();
            let grad = grads.dev(owner).get(x).unwrap().to_vec();
            let p = layer.params.dev_mut(owner).get_mut(x).unwrap();
            layer.opt.get_mut(&x).unwrap().update(&e.adam, p, &grad);
        }
        for d in 0..nd {
            let dev = DeviceId(d);
            let resident: Vec<usize> = layer.params.dev(dev).chunks().collect();
            for x in resident {
                if !layer.shards.contains(x, dev) {
                    layer.params.dev_mut(dev).remove(x);
                }
            }
        }
        layer.predictor.observe(&realized);
        loss
    }

    #[test]
    fn l1_step_matches_seed_oracle_bitwise() {
        // The L=1 multi-layer engine must remain bit-identical to the seed
        // single-layer engine (transcribed above) — parameters, Adam
        // moments, and loss.
        let dims = reference_dims();
        let sources = 4;
        let mut a = FssdpEngine::new_reference_layers(dims, 1, Topology::cluster_a(2, 2), 13);
        let mut b = FssdpEngine::new_reference_layers(dims, 1, Topology::cluster_a(2, 2), 13);
        for i in 0..3 {
            let sa = a.step(i, sources).unwrap();
            let lb = seed_oracle_step(&mut b, i, sources);
            assert_eq!(sa.loss.to_bits(), lb.to_bits(), "iter {i}: loss must be bit-identical");
        }
        for e in 0..dims.experts {
            assert_eq!(a.expert_chunk(e), b.expert_chunk(e), "expert {e} params");
            let (oa, ob) = (&a.layers[0].opt[&e], &b.layers[0].opt[&e]);
            assert_eq!(oa.m, ob.m, "expert {e} Adam m");
            assert_eq!(oa.v, ob.v, "expert {e} Adam v");
            assert_eq!(oa.t, ob.t, "expert {e} Adam t");
        }
        assert_eq!(
            a.layers[0].predictor.history(),
            b.layers[0].predictor.history(),
            "predictor windows must agree"
        );
    }

    #[test]
    fn reshard_every_keeps_partitions_and_training_health() {
        let dims = reference_dims();
        let mut e = FssdpEngine::new_reference_layers(dims, 3, Topology::cluster_a(2, 2), 9);
        e.reshard_every = 2;
        let stats = e.run_span(0, 6, 4).unwrap();
        assert_eq!(stats.len(), 6);
        assert!(stats[5].loss < stats[0].loss, "loss must still decrease across re-shards");
        for l in 0..3 {
            assert!(e.layers[l].shards.is_partition(), "layer {l} must stay a partition");
            for x in 0..dims.experts {
                // owner really holds the chunk after migrations
                let _ = e.expert_chunk_at(l, x);
            }
        }
        // joint slot balance across layers (Figure 8's invariant)
        let plan = ShardingPlan {
            layers: e.layers.iter().map(|ls| ls.shards.clone()).collect(),
        };
        assert_eq!(plan.slot_imbalance(4), 0, "3*8 experts over 4 devices");
    }

    #[test]
    fn snapshot_captures_owner_layout() {
        let mut e =
            FssdpEngine::new_reference_layers(reference_dims(), 2, Topology::cluster_a(2, 2), 5);
        e.step(0, 4).unwrap();
        let s = e.snapshot(1, 4);
        assert_eq!(s.step, 1);
        assert_eq!(s.data_shards, 4);
        assert_eq!(s.num_layers(), 2);
        for (l, layer) in s.layers.iter().enumerate() {
            assert_eq!(layer.experts.len(), e.dims.experts);
            for (x, &o) in layer.owners.iter().enumerate() {
                assert_eq!(o, e.owner_at(l, x).0);
                assert_eq!(layer.experts[x].chunk.as_slice(), e.expert_chunk_at(l, x));
            }
        }
    }

    #[test]
    fn threaded_expert_loop_is_bit_identical() {
        // The scoped-thread split of the expert loops merges results in
        // route order — parameters, Adam moments, and loss must be
        // bit-identical to the in-line loop for any thread count.
        let dims = reference_dims();
        let run = |threads: usize| {
            let mut e = FssdpEngine::new_reference_layers(dims, 3, Topology::cluster_a(2, 2), 17);
            e.compute_threads = threads;
            let stats: Vec<EngineStats> =
                (0..3).map(|i| e.step(i, 4).unwrap()).collect();
            let opt_bits: Vec<Vec<f32>> = (0..3)
                .flat_map(|l| {
                    (0..dims.experts).map(move |x| (l, x)).collect::<Vec<_>>()
                })
                .map(|(l, x)| e.layers[l].opt[&x].m.clone())
                .collect();
            (all_chunks(&e), opt_bits, stats)
        };
        let (c1, m1, s1) = run(1);
        for threads in [2, 4, 7] {
            let (ct, mt, st) = run(threads);
            assert_eq!(c1, ct, "params must be bit-identical at {threads} threads");
            assert_eq!(m1, mt, "Adam moments must be bit-identical at {threads} threads");
            for (a, b) in s1.iter().zip(st.iter()) {
                assert_eq!(
                    a.loss.to_bits(),
                    b.loss.to_bits(),
                    "loss must be bit-identical at {threads} threads"
                );
            }
        }
    }

    #[test]
    fn fast_mode_is_deterministic_and_still_trains() {
        // The Fast tier gives up bit-identity to Reference, not
        // determinism: with the mode and thread count fixed, repeated runs
        // must agree to the bit — and because per-key work merges in route
        // order into zeroed buffers, the threaded split reproduces the
        // in-line loop exactly even in Fast mode.
        let dims = reference_dims();
        let run = |threads: usize| {
            let mut e =
                FssdpEngine::new_reference_layers(dims, 3, Topology::cluster_a(2, 2), 17);
            e.set_compute_mode(ComputeMode::Fast);
            assert_eq!(e.compute_mode(), Some(ComputeMode::Fast));
            assert_eq!(e.backend(), "fast");
            e.compute_threads = threads;
            let losses: Vec<u64> =
                (0..4).map(|i| e.step(i, 4).unwrap().loss.to_bits()).collect();
            (all_chunks(&e), losses)
        };
        let (c_a, l_a) = run(2);
        let (c_b, l_b) = run(2);
        assert_eq!(c_a, c_b, "Fast mode must be run-to-run deterministic at fixed threads");
        assert_eq!(l_a, l_b, "loss bits must repeat run to run");
        let (c_c, l_c) = run(1);
        assert_eq!(c_a, c_c, "route-order merge must equal the in-line Fast loop");
        assert_eq!(l_a, l_c);
        let (first, last) = (f64::from_bits(l_a[0]), f64::from_bits(l_a[3]));
        assert!(last < first, "Fast mode must still train: {first} -> {last}");
    }

    #[test]
    fn workspace_allocations_stay_flat_across_a_span() {
        // 1 device: the placement is constant, so after the first
        // iteration every buffer request must be served from the pool —
        // the regression lock on per-iteration allocation discipline.
        let mut e =
            FssdpEngine::new_reference_layers(reference_dims(), 2, Topology::flat(1, 1e9), 3);
        let stats = e.run_span(0, 10, 4).unwrap();
        assert!(stats[0].ws_allocs > 0, "first iteration must populate the pool");
        for (i, s) in stats.iter().enumerate().skip(1) {
            assert_eq!(s.ws_allocs, 0, "iteration {i} allocated {} fresh buffers", s.ws_allocs);
        }
        let ws = e.workspace_stats();
        assert!(
            ws.pool_reused > ws.pool_allocated,
            "steady state must reuse: {ws:?}"
        );

        // multi-device: placements evolve with the load predictions, but
        // the pool still absorbs the steady state — total fresh
        // allocations stay bounded by the high-water mark while reuse
        // keeps growing.
        let mut e =
            FssdpEngine::new_reference_layers(reference_dims(), 2, Topology::cluster_a(2, 2), 3);
        e.run_span(0, 10, 4).unwrap();
        let ws = e.workspace_stats();
        assert!(ws.pool_reused > 2 * ws.pool_allocated, "cluster run must mostly reuse: {ws:?}");
    }

    #[test]
    fn tracing_off_by_default_and_on_keeps_allocations_flat() {
        // Telemetry defaults off: no recorder, no events, no overhead.
        let mut e =
            FssdpEngine::new_reference_layers(reference_dims(), 2, Topology::flat(1, 1e9), 3);
        e.run_span(0, 3, 4).unwrap();
        assert!(e.trace_events().is_none(), "tracing must be off unless requested");

        // With a recorder installed, the numeric hot path still serves
        // every buffer from the pool after warm-up — trace events live in
        // the recorder's own vec, outside the workspace accounting.
        let mut e =
            FssdpEngine::new_reference_layers(reference_dims(), 2, Topology::flat(1, 1e9), 3);
        e.tracer = Some(crate::telemetry::TraceRecorder::new(0));
        let stats = e.run_span(0, 10, 4).unwrap();
        for (i, s) in stats.iter().enumerate().skip(1) {
            assert_eq!(s.ws_allocs, 0, "traced iteration {i} allocated {} buffers", s.ws_allocs);
        }
        let events = e.trace_events().expect("recorder installed");
        // 2 layers × 10 iters: spag_issue/materialize/gate/expert_fwd +
        // sprs_issue/sprs_wait/adam per layer, expert_bwd on the inner
        // layer only — 15 spans per iteration.
        assert_eq!(events.len(), 10 * (2 * 7 + 1), "sequential span event count");
        assert!(events.iter().all(|ev| ev.rank == 0), "sequential events carry rank 0");
    }

    #[test]
    fn metering_keeps_workspace_allocations_flat() {
        // The memory ledger reads pool byte counts and pushes samples into
        // the meter's own vecs — nothing on the numeric hot path may
        // allocate for it, so the steady-state lock holds unchanged.
        let mut e =
            FssdpEngine::new_reference_layers(reference_dims(), 2, Topology::flat(1, 1e9), 3);
        e.meter = Some(crate::metrics::meter::StepMeter::new(0));
        let stats = e.run_span(0, 10, 4).unwrap();
        for (i, s) in stats.iter().enumerate().skip(1) {
            assert_eq!(s.ws_allocs, 0, "metered iteration {i} allocated {} buffers", s.ws_allocs);
        }
        let m = e.meter_samples().expect("meter installed");
        assert_eq!(m.mem_samples().len(), 10 * 2, "10 iters x 2 layers x 1 device");
        assert_eq!(m.load_samples().len(), 10 * 2);
    }

    #[test]
    fn step_phase_timers_accumulate_and_drain() {
        let mut e =
            FssdpEngine::new_reference_layers(reference_dims(), 2, Topology::cluster_a(2, 2), 5);
        e.run_span(0, 2, 4).unwrap();
        let p = e.take_phases();
        assert_eq!(p.steps, 2);
        assert!(p.total() > Duration::ZERO, "phases must record wall clock");
        assert_eq!(e.phases().steps, 0, "take_phases resets the accumulator");
    }
}
