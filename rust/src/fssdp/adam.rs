//! Host-side Adam optimizer for the numeric FSSDP engine: each MoE shard
//! owner updates its expert chunks after SparseReduceScatter delivers the
//! summed gradients — exactly the "one global copy of optimizer state"
//! design of FSSDP (§3.2). Semantics match `python/compile/model.py`
//! (`adam_update`), so the engine's updates are comparable to the AOT
//! train step.

/// Adam hyper-parameters (Kingma & Ba defaults).
#[derive(Debug, Clone, Copy)]
pub struct AdamCfg {
    pub lr: f32,
    pub b1: f32,
    pub b2: f32,
    pub eps: f32,
}

impl Default for AdamCfg {
    fn default() -> Self {
        AdamCfg { lr: 1e-3, b1: 0.9, b2: 0.999, eps: 1e-8 }
    }
}

/// Optimizer state for one parameter chunk.
#[derive(Debug, Clone)]
pub struct AdamState {
    pub m: Vec<f32>,
    pub v: Vec<f32>,
    pub t: u32,
}

impl AdamState {
    pub fn new(len: usize) -> AdamState {
        AdamState { m: vec![0.0; len], v: vec![0.0; len], t: 0 }
    }

    /// In-place Adam step on `params` with gradient `grad`.
    pub fn update(&mut self, cfg: &AdamCfg, params: &mut [f32], grad: &[f32]) {
        assert_eq!(params.len(), grad.len());
        assert_eq!(params.len(), self.m.len());
        self.t += 1;
        let b1t = 1.0 - cfg.b1.powi(self.t as i32);
        let b2t = 1.0 - cfg.b2.powi(self.t as i32);
        for i in 0..params.len() {
            let g = grad[i];
            self.m[i] = cfg.b1 * self.m[i] + (1.0 - cfg.b1) * g;
            self.v[i] = cfg.b2 * self.v[i] + (1.0 - cfg.b2) * g * g;
            let mhat = self.m[i] / b1t;
            let vhat = self.v[i] / b2t;
            params[i] -= cfg.lr * mhat / (vhat.sqrt() + cfg.eps);
        }
    }

    /// Bytes of optimizer state (for memory reports).
    pub fn bytes(&self) -> usize {
        (self.m.len() + self.v.len()) * 4 + 4
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn first_step_matches_closed_form() {
        // after one step mhat = g, vhat = g²: Δ = lr·g/(|g|+eps) ≈ lr·sign(g)
        let cfg = AdamCfg { lr: 0.1, ..Default::default() };
        let mut st = AdamState::new(2);
        let mut p = vec![1.0f32, -2.0];
        st.update(&cfg, &mut p, &[0.5, -0.25]);
        assert!((p[0] - (1.0 - 0.1)).abs() < 1e-4, "{}", p[0]);
        assert!((p[1] - (-2.0 + 0.1)).abs() < 1e-4, "{}", p[1]);
        assert_eq!(st.t, 1);
    }

    #[test]
    fn zero_grad_no_move() {
        let cfg = AdamCfg::default();
        let mut st = AdamState::new(3);
        let mut p = vec![1.0f32, 2.0, 3.0];
        let orig = p.clone();
        st.update(&cfg, &mut p, &[0.0; 3]);
        assert_eq!(p, orig);
    }

    #[test]
    fn descends_quadratic() {
        // minimize f(x) = x² from x=3
        let cfg = AdamCfg { lr: 0.05, ..Default::default() };
        let mut st = AdamState::new(1);
        let mut p = vec![3.0f32];
        for _ in 0..500 {
            let g = 2.0 * p[0];
            st.update(&cfg, &mut p, &[g]);
        }
        assert!(p[0].abs() < 0.05, "did not converge: {}", p[0]);
    }
}
