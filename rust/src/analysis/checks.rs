//! The four static checks over an extracted [`SpanModel`]: match
//! completeness, deadlock freedom, wire safety, and resource discipline.
//! Each check returns human-readable diagnostics (empty = pass); the
//! driver aggregates them into one nonzero-exit report.
//!
//! [`SpanModel`]: super::model::SpanModel

use std::collections::BTreeMap;

use crate::placement::Placement;
use crate::spmd::comm::Tag;
use crate::spmd::transport::socket::{HEADER_LEN, MAX_FRAME_LEN};
use crate::topology::DeviceId;

use super::model::{OpKind, SpanModel, SymOp};

fn fmt_tag(t: &Tag) -> String {
    format!("iter {} layer {} {:?} a={} b={}", t.iter, t.layer, t.kind, t.a, t.b)
}

/// Check 1 — match completeness: on every directed link, each tag's send
/// count equals its recv count. Orphans are reported with rank, iter,
/// layer, and tag.
pub(crate) fn check_matching(model: &SpanModel) -> Vec<String> {
    let mut sends: BTreeMap<(usize, usize, Tag), usize> = BTreeMap::new();
    let mut recvs: BTreeMap<(usize, usize, Tag), usize> = BTreeMap::new();
    for (r, ops) in model.ranks.iter().enumerate() {
        for op in ops {
            match op.kind {
                OpKind::Send { dst } => *sends.entry((r, dst, op.tag)).or_default() += 1,
                OpKind::Recv { src } => *recvs.entry((src, r, op.tag)).or_default() += 1,
            }
        }
    }
    let mut out = Vec::new();
    for ((src, dst, tag), &n) in &sends {
        let m = recvs.get(&(*src, *dst, *tag)).copied().unwrap_or(0);
        if m != n {
            out.push(format!(
                "orphan send: rank {src} -> rank {dst}, {}: sent {n}x, received {m}x",
                fmt_tag(tag)
            ));
        }
    }
    for ((src, dst, tag), &m) in &recvs {
        if !sends.contains_key(&(*src, *dst, *tag)) {
            out.push(format!(
                "orphan recv: rank {dst} <- rank {src}, {}: received {m}x, never sent",
                fmt_tag(tag)
            ));
        }
    }
    out
}

/// Check 2 — deadlock freedom: build the wait-for graph over blocking
/// receives and verify it is acyclic.
///
/// Nodes are receives. A receive depends on (a) the previous receive in
/// its own rank's program (control cannot reach it earlier) and (b) the
/// last receive preceding its matching send in the *sender's* program
/// (sends never block — unbounded links — so a send is issued once every
/// blocking op before it completed). Tag stashing removes per-link
/// head-of-line edges: an early arrival with another tag parks in the
/// stash. A cycle is a real schedule deadlock and is printed hop by hop.
pub(crate) fn check_deadlock(model: &SpanModel) -> Vec<String> {
    // Pair the i-th send of a (src, dst, tag) key with its i-th recv
    // (per-tag FIFO; ambiguous reuse is flagged by the wire check).
    struct Node {
        rank: usize,
        src: usize,
        tag: Tag,
        deps: Vec<usize>,
    }
    let mut nodes: Vec<Node> = Vec::new();
    // (rank, op index) of each recv → node id; send position lists.
    let mut recv_ids: BTreeMap<(usize, usize), usize> = BTreeMap::new();
    let mut send_pos: BTreeMap<(usize, usize, Tag), Vec<usize>> = BTreeMap::new();
    let mut last_recv_before: Vec<Vec<Option<usize>>> = Vec::new(); // per rank, per op idx
    for (r, ops) in model.ranks.iter().enumerate() {
        let mut last: Option<usize> = None; // node id of most recent recv
        let mut befores = Vec::with_capacity(ops.len());
        for (i, op) in ops.iter().enumerate() {
            befores.push(last);
            match op.kind {
                OpKind::Send { dst } => {
                    send_pos.entry((r, dst, op.tag)).or_default().push(i);
                }
                OpKind::Recv { src } => {
                    let id = nodes.len();
                    nodes.push(Node { rank: r, src, tag: op.tag, deps: Vec::new() });
                    recv_ids.insert((r, i), id);
                    if let Some(prev) = last {
                        nodes[id].deps.push(prev); // program order
                    }
                    last = Some(id);
                }
            }
        }
        last_recv_before.push(befores);
    }
    // Cross edges: recv → the sender's last recv before the matching send.
    let mut match_counter: BTreeMap<(usize, usize, Tag), usize> = BTreeMap::new();
    for (r, ops) in model.ranks.iter().enumerate() {
        for (i, op) in ops.iter().enumerate() {
            if let OpKind::Recv { src } = op.kind {
                let key = (src, r, op.tag);
                let nth = match_counter.entry(key).or_default();
                let pos = send_pos.get(&key).and_then(|v| v.get(*nth).copied());
                *nth += 1;
                let Some(send_i) = pos else {
                    continue; // unmatched — the matching check reports it
                };
                if let Some(dep) = last_recv_before[src][send_i] {
                    let id = recv_ids[&(r, i)];
                    nodes[id].deps.push(dep);
                }
            }
        }
    }
    // DFS cycle detection (iterative; colors 0=white 1=gray 2=black).
    let mut color = vec![0u8; nodes.len()];
    for start in 0..nodes.len() {
        if color[start] != 0 {
            continue;
        }
        let mut stack: Vec<(usize, usize)> = vec![(start, 0)];
        let mut path: Vec<usize> = vec![start];
        color[start] = 1;
        while let Some(&(v, next)) = stack.last() {
            if next < nodes[v].deps.len() {
                stack.last_mut().expect("nonempty").1 += 1;
                let w = nodes[v].deps[next];
                match color[w] {
                    0 => {
                        color[w] = 1;
                        stack.push((w, 0));
                        path.push(w);
                    }
                    1 => {
                        // Cycle: slice the current path from w to v.
                        let from = path.iter().position(|&x| x == w).unwrap_or(0);
                        let mut hops: Vec<String> = path[from..]
                            .iter()
                            .map(|&id| {
                                let n = &nodes[id];
                                format!(
                                    "rank {} waits for {} from rank {}",
                                    n.rank,
                                    fmt_tag(&n.tag),
                                    n.src
                                )
                            })
                            .collect();
                        hops.push(hops[0].clone()); // close the loop visibly
                        return vec![format!(
                            "deadlock cycle ({} waits):\n    {}",
                            path.len() - from,
                            hops.join("\n    -> ")
                        )];
                    }
                    _ => {}
                }
            } else {
                color[v] = 2;
                stack.pop();
                path.pop();
            }
        }
    }
    Vec::new()
}

/// Check 3 — wire safety: every payload fits [`MAX_FRAME_LEN`] under the
/// socket codec's header (`check_frames` = socket transport), and no
/// `(iter, layer, kind, a, b)` tag is sent twice on one directed link
/// (tag matching would pair the receives ambiguously). `row_bound` caps
/// the content-dependent exchanges: at top-2 gating every source routes at
/// most `2 · tokens` rows of `d_model` floats.
pub(crate) fn check_wire(model: &SpanModel, check_frames: bool, row_bound: usize) -> Vec<String> {
    let mut out = Vec::new();
    let mut seen: BTreeMap<(usize, usize, Tag), usize> = BTreeMap::new();
    for (r, ops) in model.ranks.iter().enumerate() {
        for op in ops {
            let OpKind::Send { dst } = op.kind else { continue };
            *seen.entry((r, dst, op.tag)).or_default() += 1;
            if check_frames {
                let floats = op.floats.unwrap_or(row_bound);
                let frame = HEADER_LEN + floats * 4;
                if frame > MAX_FRAME_LEN {
                    out.push(format!(
                        "oversized frame: rank {r} -> rank {dst}, {}: {frame} bytes \
                         ({floats} floats + {HEADER_LEN}B header) exceeds MAX_FRAME_LEN \
                         = {MAX_FRAME_LEN}",
                        fmt_tag(&op.tag)
                    ));
                }
            }
        }
    }
    for ((src, dst, tag), n) in seen {
        if n > 1 {
            out.push(format!(
                "ambiguous tag reuse: rank {src} -> rank {dst}, {}: {n} in-flight messages \
                 share one matching key",
                fmt_tag(&tag)
            ));
        }
    }
    out
}

/// Check 4 — resource discipline: walk each iteration's plans per rank and
/// verify chunk-store conservation (spAG never double-delivers, deferred
/// fan-out sends have an earlier-stage inbound chunk, the plan placement
/// materializes fully), gradient-buffer discipline (spRS sends and reduces
/// touch only live buffers, owners end the stage loop holding their
/// shards), and the recycle ledger (every buffer a rank takes for the
/// iteration is returned or retained as an owned shard — the invariant
/// behind the `ws_allocs == 0` steady state). Shard-partition exactness
/// across reshard migrations is checked by the driver per span.
pub(crate) fn check_resources(model: &SpanModel, shards: &[Placement], start: u64) -> Vec<String> {
    let mut out = Vec::new();
    let nd = model.ranks.len();
    for (k, plans) in model.plans.iter().enumerate() {
        let iter = start + k as u64;
        for (l, plan) in plans.iter().enumerate() {
            for r in 0..nd {
                let me = DeviceId(r);
                // ---- spAG: owned shards in, placement materialized out ----
                let mut resident: Vec<bool> = (0..shards[l].num_chunks())
                    .map(|c| shards[l].contains(c, me))
                    .collect();
                // ledger: buffers taken (recvs + grad zero-fills) must be
                // returned (recycled/released) or retained as owned shards
                let mut taken = 0usize;
                let mut returned = 0usize;
                for stage in 0..plan.spag.num_stages {
                    // deferred sends of this stage need the chunk already
                    // resident (owned, or landed at an earlier stage)
                    for t in plan.spag.transfers.iter().filter(|t| t.stage == stage) {
                        if t.src.0 == r && !resident[t.chunk] {
                            out.push(format!(
                                "iter {iter} layer {l}: rank {r} must forward chunk {} at \
                                 stage {stage} but it is neither owned nor delivered by an \
                                 earlier stage",
                                t.chunk
                            ));
                        }
                    }
                    for t in plan.spag.transfers.iter().filter(|t| t.stage == stage) {
                        if t.dst.0 == r {
                            if resident[t.chunk] {
                                out.push(format!(
                                    "iter {iter} layer {l}: spAG delivers chunk {} to rank \
                                     {r} twice (stage {stage}) — the replica would leak",
                                    t.chunk
                                ));
                            }
                            resident[t.chunk] = true;
                            taken += 1;
                        }
                    }
                }
                for c in plan.placement.chunks_on_iter(me) {
                    if !resident[c] {
                        out.push(format!(
                            "iter {iter} layer {l}: placement expects chunk {c} on rank {r} \
                             but no spAG transfer delivers it"
                        ));
                    }
                }
                // settle releases everything outside the owner partition
                for (c, res) in resident.iter().enumerate() {
                    if *res && !shards[l].contains(c, me) {
                        returned += 1;
                    }
                }
                // ---- spRS: gradient buffers live exactly per placement ----
                let mut grads: Vec<bool> = (0..shards[l].num_chunks())
                    .map(|c| plan.placement.contains(c, me))
                    .collect();
                taken += grads.iter().filter(|g| **g).count();
                for stage in 0..plan.sprs.num_stages {
                    for t in plan.sprs.transfers.iter().filter(|t| t.stage == stage) {
                        if t.src.0 == r && !grads[t.chunk] {
                            out.push(format!(
                                "iter {iter} layer {l}: spRS rank {r} sends gradient chunk \
                                 {} at stage {stage} without holding it",
                                t.chunk
                            ));
                        }
                    }
                    for t in plan.sprs.transfers.iter().filter(|t| t.stage == stage) {
                        if t.dst.0 == r {
                            if t.reduce {
                                if !grads[t.chunk] {
                                    out.push(format!(
                                        "iter {iter} layer {l}: spRS reduce into rank {r} \
                                         lacks accumulator chunk {}",
                                        t.chunk
                                    ));
                                }
                                taken += 1; // the wire buffer…
                                returned += 1; // …is consumed and recycled
                            } else {
                                if grads[t.chunk] {
                                    out.push(format!(
                                        "iter {iter} layer {l}: spRS insert of chunk {} \
                                         overwrites rank {r}'s live accumulation",
                                        t.chunk
                                    ));
                                }
                                grads[t.chunk] = true;
                                taken += 1;
                            }
                        }
                    }
                }
                for (c, live) in grads.iter().enumerate() {
                    if *live && !shards[l].contains(c, me) {
                        returned += 1; // scatter releases non-owned
                    }
                }
                // owners must end the stage loop holding their shards
                for c in shards[l].chunks_on_iter(me) {
                    if !grads[c] {
                        out.push(format!(
                            "iter {iter} layer {l}: owner rank {r} ends spRS without \
                             gradient chunk {c}"
                        ));
                    }
                }
                // iteration teardown recycles the owned gradient buffers
                returned += shards[l].chunks_on_iter(me).filter(|&c| grads[c]).count();
                if taken != returned {
                    out.push(format!(
                        "iter {iter} layer {l}: rank {r} recycle ledger unbalanced: took \
                         {taken} buffers, returned {returned}"
                    ));
                }
            }
        }
    }
    out
}

/// Shard-partition exactness: every chunk of every layer has exactly one
/// owner. Run at span entry and after every reshard migration.
pub(crate) fn check_partition(shards: &[Placement], nd: usize, iter: u64) -> Vec<String> {
    let mut out = Vec::new();
    for (l, p) in shards.iter().enumerate() {
        for c in 0..p.num_chunks() {
            let holders: Vec<usize> = p.holders(c).map(|d| d.0).collect();
            if holders.len() != 1 {
                out.push(format!(
                    "iter {iter} layer {l}: chunk {c} owned by {:?} after reshard — the \
                     shard map must stay an exact partition over {nd} ranks",
                    holders
                ));
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::super::model::{emit_barrier_round, OpKind, SpanModel, SymOp};
    use super::*;
    use crate::spmd::comm::MsgKind;

    fn empty_model(nd: usize) -> SpanModel {
        SpanModel { ranks: (0..nd).map(|_| Vec::new()).collect(), plans: Vec::new() }
    }

    #[test]
    fn modeled_barrier_round_is_clean_and_matched() {
        let mut m = empty_model(3);
        emit_barrier_round(&mut m.ranks, 0, false);
        emit_barrier_round(&mut m.ranks, 1, false); // sequence numbers disambiguate
        assert!(check_matching(&m).is_empty());
        assert!(check_deadlock(&m).is_empty());
        assert!(check_wire(&m, true, 0).is_empty());
    }

    #[test]
    fn swapped_barrier_round_prints_a_cycle() {
        let mut m = empty_model(2);
        emit_barrier_round(&mut m.ranks, 0, true);
        let diags = check_deadlock(&m);
        assert_eq!(diags.len(), 1, "{diags:?}");
        assert!(diags[0].contains("deadlock cycle"), "{}", diags[0]);
        assert!(diags[0].contains("rank 0 waits for"), "{}", diags[0]);
        assert!(diags[0].contains("rank 1 waits for"), "{}", diags[0]);
    }

    #[test]
    fn orphan_send_and_recv_are_reported_with_tags() {
        let mut m = empty_model(2);
        let t = Tag { iter: 3, kind: MsgKind::Ctrl, layer: 1, a: 9, b: 0 };
        m.ranks[0].push(SymOp { kind: OpKind::Send { dst: 1 }, tag: t, floats: Some(4) });
        let diags = check_matching(&m);
        assert_eq!(diags.len(), 1);
        assert!(diags[0].contains("orphan send"), "{}", diags[0]);
        assert!(diags[0].contains("iter 3 layer 1"), "{}", diags[0]);
        m.ranks[1].push(SymOp { kind: OpKind::Recv { src: 0 }, tag: t, floats: Some(4) });
        assert!(check_matching(&m).is_empty());
        m.ranks[1].push(SymOp {
            kind: OpKind::Recv { src: 0 },
            tag: Tag { iter: 4, ..t },
            floats: None,
        });
        let diags = check_matching(&m);
        assert_eq!(diags.len(), 1);
        assert!(diags[0].contains("orphan recv"), "{}", diags[0]);
    }

    #[test]
    fn frame_cap_and_tag_reuse_are_flagged() {
        let mut m = empty_model(2);
        let t = Tag { iter: 0, kind: MsgKind::SpagChunk, layer: 0, a: 0, b: 0 };
        let too_big = (MAX_FRAME_LEN - HEADER_LEN) / 4 + 1;
        m.ranks[0].push(SymOp { kind: OpKind::Send { dst: 1 }, tag: t, floats: Some(too_big) });
        let diags = check_wire(&m, true, 0);
        assert_eq!(diags.len(), 1, "{diags:?}");
        assert!(diags[0].contains("oversized frame"), "{}", diags[0]);
        // the in-proc fabric has no frame cap
        assert!(check_wire(&m, false, 0).is_empty());
        m.ranks[0].push(SymOp { kind: OpKind::Send { dst: 1 }, tag: t, floats: Some(1) });
        let diags = check_wire(&m, false, 0);
        assert_eq!(diags.len(), 1, "{diags:?}");
        assert!(diags[0].contains("ambiguous tag reuse"), "{}", diags[0]);
    }

    #[test]
    fn double_owned_chunk_fails_the_partition_check() {
        let mut shards = vec![Placement::round_robin(4, 2)];
        assert!(check_partition(&shards, 2, 8).is_empty());
        shards[0].add(0, DeviceId(1));
        let diags = check_partition(&shards, 2, 8);
        assert_eq!(diags.len(), 1);
        assert!(diags[0].contains("chunk 0 owned by [0, 1]"), "{}", diags[0]);
        assert!(diags[0].contains("iter 8 layer 0"), "{}", diags[0]);
    }
}
