//! Symbolic schedule extraction: replay the plan-building path of a span
//! and enumerate every rank's communication ops **in program order**,
//! without executing a single kernel.
//!
//! The extractor mirrors `spmd::rank_main` exactly:
//!
//! * plans are rebuilt per iteration from the replicated
//!   [`LoadPredictor`] state (predict all layers, then observe all layers
//!   — the same ordering the executor follows in both the synchronous and
//!   the §4.3 overlap schedule);
//! * spAG sends split into begin-time sends (chunks owned per the shard
//!   partition, hence resident) and deferred fan-out sends emitted inside
//!   the staged finish — the exact split `exec::RankSpag` performs;
//! * spRS is stage-synchronous: stage-0 sends at `begin`, later stages'
//!   sends before that stage's plan-ordered receives (`exec::RankSprs`);
//! * the gate / combine / cotangent exchanges are allgathers tagged
//!   `(iter, kind, layer, sender, 0)` with the executor's exact fan-out.
//!
//! The eager next-iteration issue (`sched::Overlap::eager_issue`) sends
//! the *same* tagged messages earlier in wall-clock time than this model
//! places them; the multiset is identical and an earlier send can only
//! shrink the wait-for graph, so checking the model is conservative.
//!
//! [`LoadPredictor`]: crate::loadsim::LoadPredictor

use crate::collectives::sparse::SparsePlan;
use crate::fssdp::{build_iter_plan, IterPlan, LayerDims};
use crate::loadsim::LoadPredictor;
use crate::materialize::MatConstraints;
use crate::placement::Placement;
use crate::spmd::comm::{AuditEvent, MsgKind, Tag};
use crate::topology::{DeviceId, Topology};

/// Direction + peer of one symbolic communication op.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub(crate) enum OpKind {
    Send { dst: usize },
    Recv { src: usize },
}

/// One entry of a rank's symbolic program: a tagged send or receive.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub(crate) struct SymOp {
    pub kind: OpKind,
    pub tag: Tag,
    /// Payload length in floats; `None` when content-dependent (gate
    /// routing decides the combine/cotangent row counts). The match and
    /// deadlock checks ignore sizes; the wire check bounds `None` by the
    /// worst-case routed-row payload.
    pub floats: Option<usize>,
}

/// Inputs of one reshard-free span, mirroring what `spmd::run_span` hands
/// each rank thread.
pub(crate) struct SpanSpec<'a> {
    pub topo: &'a Topology,
    pub dims: LayerDims,
    /// Per-layer owner partitions at span entry.
    pub shards: &'a [Placement],
    pub cons: MatConstraints,
    pub sources: usize,
    pub start: u64,
    pub iters: usize,
    pub overlap: bool,
}

/// The extracted model: every rank's ops in program order, plus the
/// per-iteration plans (the resource check re-walks them).
pub(crate) struct SpanModel {
    /// `ranks[r]` = rank `r`'s symbolic program for the span.
    pub ranks: Vec<Vec<SymOp>>,
    /// `plans[k][l]` = iteration `start + k`'s layer-`l` plan.
    pub plans: Vec<Vec<IterPlan>>,
}

fn spag_tag(iter: u64, layer: usize, chunk: usize, stage: usize) -> Tag {
    Tag { iter, kind: MsgKind::SpagChunk, layer, a: chunk, b: stage }
}

fn sprs_tag(iter: u64, layer: usize, chunk: usize, stage: usize) -> Tag {
    Tag { iter, kind: MsgKind::SprsChunk, layer, a: chunk, b: stage }
}

/// Begin-time spAG sends: every transfer sourced here whose chunk is owned
/// (owned ⇒ resident when `RankSpag::begin` runs — the settle of the
/// previous iteration retained exactly the shard chunks).
fn emit_spag_begin(
    ops: &mut Vec<SymOp>,
    r: usize,
    iter: u64,
    layer: usize,
    plan: &SparsePlan,
    owned: &Placement,
    chunk_len: usize,
) {
    for t in &plan.transfers {
        if t.src.0 == r && owned.contains(t.chunk, DeviceId(r)) {
            ops.push(SymOp {
                kind: OpKind::Send { dst: t.dst.0 },
                tag: spag_tag(iter, layer, t.chunk, t.stage),
                floats: Some(chunk_len),
            });
        }
    }
}

/// Staged spAG completion: per stage, the deferred fan-out sends of
/// chunks that just landed, then this rank's receives in plan order. The
/// polling executor may interleave differently; this serialization is
/// causally consistent (a deferred stage-`s` send only needs an inbound
/// chunk from a stage `< s`, which the resource check enforces).
fn emit_spag_finish(
    ops: &mut Vec<SymOp>,
    r: usize,
    iter: u64,
    layer: usize,
    plan: &SparsePlan,
    owned: &Placement,
    chunk_len: usize,
) {
    for stage in 0..plan.num_stages {
        for t in &plan.transfers {
            if t.stage == stage && t.src.0 == r && !owned.contains(t.chunk, DeviceId(r)) {
                ops.push(SymOp {
                    kind: OpKind::Send { dst: t.dst.0 },
                    tag: spag_tag(iter, layer, t.chunk, t.stage),
                    floats: Some(chunk_len),
                });
            }
        }
        for t in &plan.transfers {
            if t.stage == stage && t.dst.0 == r {
                ops.push(SymOp {
                    kind: OpKind::Recv { src: t.src.0 },
                    tag: spag_tag(iter, layer, t.chunk, t.stage),
                    floats: Some(chunk_len),
                });
            }
        }
    }
}

/// Stage-0 spRS sends (`RankSprs::begin` — the gradient buffers are final).
fn emit_sprs_begin(
    ops: &mut Vec<SymOp>,
    r: usize,
    iter: u64,
    layer: usize,
    plan: &SparsePlan,
    chunk_len: usize,
) {
    if plan.num_stages == 0 {
        return;
    }
    for t in &plan.transfers {
        if t.stage == 0 && t.src.0 == r {
            ops.push(SymOp {
                kind: OpKind::Send { dst: t.dst.0 },
                tag: sprs_tag(iter, layer, t.chunk, t.stage),
                floats: Some(chunk_len),
            });
        }
    }
}

/// The remaining spRS stage loop: per stage, later-stage sends first, then
/// this rank's receives in plan order (`RankSprs::finish`).
fn emit_sprs_finish(
    ops: &mut Vec<SymOp>,
    r: usize,
    iter: u64,
    layer: usize,
    plan: &SparsePlan,
    chunk_len: usize,
) {
    for stage in 0..plan.num_stages {
        if stage > 0 {
            for t in &plan.transfers {
                if t.stage == stage && t.src.0 == r {
                    ops.push(SymOp {
                        kind: OpKind::Send { dst: t.dst.0 },
                        tag: sprs_tag(iter, layer, t.chunk, t.stage),
                        floats: Some(chunk_len),
                    });
                }
            }
        }
        for t in &plan.transfers {
            if t.stage == stage && t.dst.0 == r {
                ops.push(SymOp {
                    kind: OpKind::Recv { src: t.src.0 },
                    tag: sprs_tag(iter, layer, t.chunk, t.stage),
                    floats: Some(chunk_len),
                });
            }
        }
    }
}

/// An allgather round `(iter, kind, layer, sender, 0)`: sends to every
/// peer, then receives in rank order (`RankComm::allgather`); the rank's
/// own contribution never touches the transport. `floats(q)` gives rank
/// `q`'s payload length, `None` when content-dependent.
fn emit_allgather(
    ops: &mut Vec<SymOp>,
    r: usize,
    nd: usize,
    iter: u64,
    kind: MsgKind,
    layer: usize,
    floats: impl Fn(usize) -> Option<usize>,
) {
    for dst in 0..nd {
        if dst != r {
            ops.push(SymOp {
                kind: OpKind::Send { dst },
                tag: Tag { iter, kind, layer, a: r, b: 0 },
                floats: floats(r),
            });
        }
    }
    for src in 0..nd {
        if src != r {
            ops.push(SymOp {
                kind: OpKind::Recv { src },
                tag: Tag { iter, kind, layer, a: src, b: 0 },
                floats: floats(src),
            });
        }
    }
}

/// A fallback-barrier round (`RankComm::barrier` on backends without a
/// native barrier): sends to every peer, then receives from every peer,
/// under one sequence number. `swapped` reverses the two phases — the
/// classic deadlock every rank blocking on receives before sending — used
/// by the `swap-barrier` mutation to prove the cycle detector fires.
pub(crate) fn emit_barrier_round(ranks: &mut [Vec<SymOp>], seq: u64, swapped: bool) {
    let nd = ranks.len();
    for (r, ops) in ranks.iter_mut().enumerate() {
        let sends: Vec<SymOp> = (0..nd)
            .filter(|&dst| dst != r)
            .map(|dst| SymOp {
                kind: OpKind::Send { dst },
                tag: Tag { iter: seq, kind: MsgKind::Barrier, layer: 0, a: r, b: 0 },
                floats: Some(0),
            })
            .collect();
        let recvs: Vec<SymOp> = (0..nd)
            .filter(|&src| src != r)
            .map(|src| SymOp {
                kind: OpKind::Recv { src },
                tag: Tag { iter: seq, kind: MsgKind::Barrier, layer: 0, a: src, b: 0 },
                floats: Some(0),
            })
            .collect();
        if swapped {
            ops.extend(recvs);
            ops.extend(sends);
        } else {
            ops.extend(sends);
            ops.extend(recvs);
        }
    }
}

/// Replay one reshard-free span symbolically: build every iteration's
/// plans from the live predictor state, emit every rank's program, then
/// feed the predictors the realized loads — the exact predict/observe
/// cadence of `rank_main`. `realized[k][l]` is iteration `start + k`'s
/// layer-`l` realized load fractions (a synthetic trajectory for the
/// static CLI, the recorded gate outcome for the runtime cross-check).
pub(crate) fn extract_span(
    spec: &SpanSpec<'_>,
    predictors: &mut [LoadPredictor],
    realized: &[Vec<Vec<f64>>],
) -> anyhow::Result<SpanModel> {
    let nd = spec.topo.num_devices();
    let nl = spec.shards.len();
    anyhow::ensure!(nl > 0, "schedule model needs at least one layer");
    anyhow::ensure!(predictors.len() == nl, "one predictor per layer");
    anyhow::ensure!(realized.len() == spec.iters, "one realized-load row per iteration");
    let clen = spec.dims.chunk_len();
    let gate_rec = 1 + 4 * spec.dims.tokens;
    let gate_cnt =
        |q: usize| (0..spec.sources).filter(|s| s % nd == q).count();

    let mut ranks: Vec<Vec<SymOp>> = (0..nd).map(|_| Vec::new()).collect();
    let mut plans_by_iter: Vec<Vec<IterPlan>> = Vec::with_capacity(spec.iters);
    for k in 0..spec.iters {
        let iter = spec.start + k as u64;
        // Plans for all layers from the span-entry shard partition and the
        // current predictor window — identical on every rank, and
        // identical whether built at the iteration top or pre-built by the
        // overlap pipeline (the predictors observe strictly before either
        // build point).
        let mut plans: Vec<IterPlan> = Vec::with_capacity(nl);
        for (l, p) in predictors.iter().enumerate() {
            plans.push(build_iter_plan(spec.topo, &spec.shards[l], &p.predict(), spec.cons)?);
        }

        for (r, ops) in ranks.iter_mut().enumerate() {
            // ---- forward sweep ----
            for l in 0..nl {
                let last_layer = l + 1 == nl;
                if spec.overlap {
                    if l == 0 {
                        emit_spag_begin(ops, r, iter, 0, &plans[0].spag, &spec.shards[0], clen);
                    }
                    emit_allgather(ops, r, nd, iter, MsgKind::Gate, l, |q| {
                        Some(gate_cnt(q) * gate_rec)
                    });
                    if !last_layer {
                        emit_spag_begin(
                            ops,
                            r,
                            iter,
                            l + 1,
                            &plans[l + 1].spag,
                            &spec.shards[l + 1],
                            clen,
                        );
                    }
                    emit_spag_finish(ops, r, iter, l, &plans[l].spag, &spec.shards[l], clen);
                } else {
                    emit_spag_begin(ops, r, iter, l, &plans[l].spag, &spec.shards[l], clen);
                    emit_spag_finish(ops, r, iter, l, &plans[l].spag, &spec.shards[l], clen);
                    emit_allgather(ops, r, nd, iter, MsgKind::Gate, l, |q| {
                        Some(gate_cnt(q) * gate_rec)
                    });
                }
                if !last_layer {
                    emit_allgather(ops, r, nd, iter, MsgKind::Combine, l, |_| None);
                } else if nl > 1 {
                    emit_allgather(ops, r, nd, iter, MsgKind::GradX, l, |_| None);
                }
            }
            // ---- backward sweep ----
            for l in (0..nl).rev() {
                if l + 1 < nl && l > 0 {
                    emit_allgather(ops, r, nd, iter, MsgKind::GradX, l, |_| None);
                }
                emit_sprs_begin(ops, r, iter, l, &plans[l].sprs, clen);
                if spec.overlap {
                    if l + 1 < nl {
                        emit_sprs_finish(ops, r, iter, l + 1, &plans[l + 1].sprs, clen);
                    }
                } else {
                    emit_sprs_finish(ops, r, iter, l, &plans[l].sprs, clen);
                }
            }
            if spec.overlap {
                emit_sprs_finish(ops, r, iter, 0, &plans[0].sprs, clen);
            }
        }

        // Every layer observes this iteration's realized loads before the
        // next iteration's plans exist (rank_main observes during the
        // forward sweep; next-iteration plans are built strictly after).
        for (l, p) in predictors.iter_mut().enumerate() {
            anyhow::ensure!(
                realized[k][l].len() == spec.dims.experts,
                "realized loads of iter {iter} layer {l} have the wrong arity"
            );
            p.observe(&realized[k][l]);
        }
        plans_by_iter.push(plans);
    }
    Ok(SpanModel { ranks, plans: plans_by_iter })
}

/// The `debug_assertions` drift guard: re-extract the span's predicted
/// multiset from the *recorded* realized loads and compare it per rank
/// against the communicator's audit log — counts per `(direction, peer,
/// tag)` always, payload lengths wherever the model knows them.
#[allow(clippy::too_many_arguments)]
pub(crate) fn verify_span_traffic(
    spec: &SpanSpec<'_>,
    predictors: &mut [LoadPredictor],
    realized: &[Vec<Vec<f64>>],
    audits: &[Vec<AuditEvent>],
) -> anyhow::Result<()> {
    use std::collections::BTreeMap;
    let model = extract_span(spec, predictors, realized)?;
    anyhow::ensure!(
        audits.len() == model.ranks.len(),
        "audit logs from {} ranks, model has {}",
        audits.len(),
        model.ranks.len()
    );
    let mut diffs: Vec<String> = Vec::new();
    for (r, (ops, audit)) in model.ranks.iter().zip(audits).enumerate() {
        // (is_send, peer, tag) → (count, expected floats if size-checked)
        let mut want: BTreeMap<(bool, usize, Tag), (usize, Option<usize>)> = BTreeMap::new();
        for op in ops {
            let (send, peer) = match op.kind {
                OpKind::Send { dst } => (true, dst),
                OpKind::Recv { src } => (false, src),
            };
            let e = want.entry((send, peer, op.tag)).or_insert((0, op.floats));
            e.0 += 1;
        }
        let mut got: BTreeMap<(bool, usize, Tag), (usize, usize)> = BTreeMap::new();
        for ev in audit {
            let e = got.entry((ev.send, ev.peer, ev.tag)).or_insert((0, ev.floats));
            e.0 += 1;
        }
        for (key, (n, floats)) in &want {
            let (dir, peer) = (if key.0 { "send to" } else { "recv from" }, key.1);
            match got.get(key) {
                None => diffs.push(format!(
                    "rank {r}: predicted {dir} rank {peer} {:?} ({n}×) never happened",
                    key.2
                )),
                Some((m, len)) => {
                    if m != n {
                        diffs.push(format!(
                            "rank {r}: {dir} rank {peer} {:?} happened {m}×, predicted {n}×",
                            key.2
                        ));
                    }
                    if let Some(f) = floats {
                        if len != f {
                            diffs.push(format!(
                                "rank {r}: {dir} rank {peer} {:?} carried {len} floats, \
                                 predicted {f}",
                                key.2
                            ));
                        }
                    }
                }
            }
        }
        for (key, (m, _)) in &got {
            if !want.contains_key(key) {
                let (dir, peer) = (if key.0 { "send to" } else { "recv from" }, key.1);
                diffs.push(format!(
                    "rank {r}: unpredicted {dir} rank {peer} {:?} ({m}×)",
                    key.2
                ));
            }
        }
    }
    if !diffs.is_empty() {
        diffs.truncate(12);
        anyhow::bail!(
            "SPMD traffic diverged from the static schedule model:\n  {}",
            diffs.join("\n  ")
        );
    }
    Ok(())
}
