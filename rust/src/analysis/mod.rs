//! Static schedule verification: prove a configuration's SPMD
//! communication schedule is fully matched, deadlock-free, wire-safe, and
//! resource-disciplined — **without executing it**.
//!
//! [`model`] replays the plan-building path (`build_iter_plan` over the
//! replicated predictor state, Algorithm 2 resharding at the configured
//! cadence, the `sched`/`exec` issue rules) and enumerates every rank's
//! tagged sends and receives in program order. [`checks`] runs four
//! analyses over that model; [`analyze_config`] drives both across a
//! window of iterations spanning every reshard boundary in the window and
//! aggregates violations into one diagnostic error (the CLI surface is
//! `hecate analyze schedule`, which exits nonzero on any violation).
//!
//! The same extractor backs a `debug_assertions` cross-check inside
//! `spmd::run_span`: every debug-build SPMD span compares its actual
//! per-rank traffic (a communicator audit log) against the model's
//! predicted multiset, so the static model cannot silently drift from the
//! executor. [`Injection`] seeds deliberate violations — a dropped
//! receive, a swapped barrier, an oversized frame, a double-owned chunk —
//! to prove each check actually fires.

pub(crate) mod checks;
pub(crate) mod model;

use crate::fssdp::{Executor, SessionConfig};
use crate::loadsim::{LoadPredictor, ModelLoadTrace};
use crate::materialize::MatConstraints;
use crate::placement::Placement;
use crate::sharding::{heterogeneous_sticky, ShardingPlan};
use crate::spmd::transport::socket::{HEADER_LEN, MAX_FRAME_LEN};
use crate::spmd::transport::TransportKind;
use crate::topology::DeviceId;

use model::{OpKind, SpanSpec, SymOp};

/// A deliberate schedule violation, seeded into the model so the mutation
/// tests (and `hecate analyze schedule --inject …`) can prove every check
/// catches what it claims to catch.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Injection {
    /// Delete the first spAG receive of the first span — its matching
    /// send becomes an orphan (match-completeness must fire).
    DropRecv,
    /// Append a fallback-barrier round with the send/receive phases
    /// swapped on every rank — the classic all-blocked-on-receives
    /// deadlock (cycle detection must fire and print the cycle).
    SwapBarrier,
    /// Inflate the first spAG send past `MAX_FRAME_LEN` (wire safety must
    /// fire; meaningful with `--transport socket`).
    OversizeFrame,
    /// Give layer 0's chunk 0 a second owner at the first reshard
    /// boundary (or at span entry when resharding is off) — the shard map
    /// stops being a partition (resource discipline must fire).
    DoubleOwn,
}

impl Injection {
    /// Parse a CLI `--inject` value.
    pub fn parse(s: &str) -> Option<Injection> {
        match s {
            "drop-recv" => Some(Injection::DropRecv),
            "swap-barrier" => Some(Injection::SwapBarrier),
            "oversize-frame" => Some(Injection::OversizeFrame),
            "double-own" => Some(Injection::DoubleOwn),
            _ => None,
        }
    }
}

/// What a clean analysis covered, for the CLI summary line.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ScheduleReport {
    /// Ranks in the communicator.
    pub ranks: usize,
    /// MoE layers in the stack.
    pub layers: usize,
    /// Iterations analyzed.
    pub iters: usize,
    /// Reshard-free spans the window split into.
    pub spans: usize,
    /// Reshard boundaries replayed.
    pub reshards: usize,
    /// Expert shards that migrated across those boundaries.
    pub experts_moved: usize,
    /// Total modeled sends across all ranks and iterations.
    pub sends: usize,
    /// Total modeled receives.
    pub recvs: usize,
    /// Largest modeled wire frame in bytes (known-size payloads).
    pub max_frame_bytes: usize,
}

fn count_ops(ranks: &[Vec<SymOp>]) -> (usize, usize, usize) {
    let (mut sends, mut recvs, mut max_floats) = (0usize, 0usize, 0usize);
    for ops in ranks {
        for op in ops {
            match op.kind {
                OpKind::Send { .. } => sends += 1,
                OpKind::Recv { .. } => recvs += 1,
            }
            if let Some(f) = op.floats {
                max_floats = max_floats.max(f);
            }
        }
    }
    (sends, recvs, max_floats)
}

/// Statically analyze `iters` iterations of `cfg`'s communication
/// schedule: replay plans and resharding from the same deterministic
/// recipe the engine uses (round-robin shards, window-5 predictors fed a
/// seeded synthetic load trajectory), extract every reshard-free span's
/// per-rank event multiset, and run the four checks. Returns the coverage
/// report, or an error aggregating every diagnostic (the CLI maps it to a
/// nonzero exit).
pub fn analyze_config(
    cfg: &SessionConfig,
    iters: usize,
    inject: Option<Injection>,
) -> anyhow::Result<ScheduleReport> {
    let topo = cfg.topology();
    let nd = topo.num_devices();
    let dims = cfg.dims;
    let nl = cfg.layers.unwrap_or(1);
    anyhow::ensure!(nl > 0, "schedule analysis needs at least one layer");
    anyhow::ensure!(iters > 0, "schedule analysis needs at least one iteration");
    let sources = cfg.data_shards.unwrap_or(nd);
    let reshard_every = cfg.reshard_every.unwrap_or(0);
    let cons = MatConstraints {
        overlap_degree: cfg.overlap_degree.unwrap_or(4),
        mem_slots: cfg.mem_slots.unwrap_or(4),
    };
    let overlap = match cfg.executor() {
        Executor::Spmd { overlap, .. } => overlap,
        Executor::Sequential => true,
    };
    let check_frames = cfg.transport() == TransportKind::Socket;
    // Worst case for the content-dependent row exchanges: top-2 gating
    // routes at most 2·tokens rows of d_model floats per source, and one
    // rank may compute every routed group.
    let row_bound = 2 * dims.tokens * sources * dims.d_model;

    // Engine-identical control-plane state at iteration 0.
    let mut shards: Vec<Placement> =
        (0..nl).map(|_| Placement::round_robin(dims.experts, nd)).collect();
    let mut predictors: Vec<LoadPredictor> =
        (0..nl).map(|_| LoadPredictor::new(dims.experts, 5)).collect();
    // The static pass has no gate kernels to realize loads; a seeded
    // locality-preserving trace drives the predictor windows (and thus
    // plan evolution) through a realistic trajectory.
    let mut trace = ModelLoadTrace::new(nl, dims.experts, cfg.seed);
    let realized_all: Vec<Vec<Vec<f64>>> = (0..iters).map(|_| trace.step()).collect();

    if inject == Some(Injection::DoubleOwn) && reshard_every == 0 {
        let owner = shards[0].holders(0).next().expect("chunk 0 has an owner");
        shards[0].add(0, DeviceId((owner.0 + 1) % nd));
    }

    let mut violations: Vec<String> = Vec::new();
    let (mut spans, mut reshards, mut experts_moved) = (0usize, 0usize, 0usize);
    let (mut sends, mut recvs, mut max_floats) = (0usize, 0usize, 0usize);
    let mut step = 0usize;
    let mut first_span = true;
    while step < iters && violations.is_empty() {
        let span_len = if reshard_every > 0 {
            (reshard_every - (step % reshard_every)).min(iters - step)
        } else {
            iters - step
        };
        violations.extend(checks::check_partition(&shards, nd, step as u64));
        if !violations.is_empty() {
            break; // a broken shard map invalidates plan building
        }
        let spec = SpanSpec {
            topo,
            dims,
            shards: &shards,
            cons,
            sources,
            start: step as u64,
            iters: span_len,
            overlap,
        };
        let mut m = model::extract_span(
            &spec,
            &mut predictors,
            &realized_all[step..step + span_len],
        )?;
        if first_span {
            match inject {
                Some(Injection::DropRecv) => {
                    // Prefer a spAG receive; any receive demonstrates the
                    // orphaned matching send either way.
                    let find = |ops: &Vec<SymOp>, spag_only: bool| {
                        ops.iter().position(|op| {
                            matches!(op.kind, OpKind::Recv { .. })
                                && (!spag_only
                                    || op.tag.kind == crate::spmd::comm::MsgKind::SpagChunk)
                        })
                    };
                    let dropped = m.ranks.iter_mut().any(|ops| {
                        if let Some(i) = find(ops, true).or_else(|| find(ops, false)) {
                            ops.remove(i);
                            true
                        } else {
                            false
                        }
                    });
                    anyhow::ensure!(dropped, "no receive to drop in this schedule");
                }
                Some(Injection::SwapBarrier) => {
                    model::emit_barrier_round(&mut m.ranks, iters as u64, true);
                }
                Some(Injection::OversizeFrame) => {
                    // Prefer a spAG send; any send exercises the frame cap.
                    let grow = |ops: &mut Vec<SymOp>, spag_only: bool| {
                        for op in ops.iter_mut() {
                            if matches!(op.kind, OpKind::Send { .. })
                                && (!spag_only
                                    || op.tag.kind == crate::spmd::comm::MsgKind::SpagChunk)
                            {
                                op.floats = Some((MAX_FRAME_LEN - HEADER_LEN) / 4 + 1);
                                return true;
                            }
                        }
                        false
                    };
                    let bumped = m.ranks.iter_mut().any(|ops| grow(ops, true) || grow(ops, false));
                    anyhow::ensure!(bumped, "no send to oversize in this schedule");
                }
                _ => {}
            }
            first_span = false;
        }
        violations.extend(checks::check_matching(&m));
        violations.extend(checks::check_deadlock(&m));
        violations.extend(checks::check_wire(&m, check_frames, row_bound));
        violations.extend(checks::check_resources(&m, &shards, step as u64));
        let (s, r, f) = count_ops(&m.ranks);
        sends += s;
        recvs += r;
        max_floats = max_floats.max(f);
        spans += 1;
        step += span_len;
        // Reshard boundary: replay Algorithm 2 exactly as `reshard_now`
        // does (sticky joint re-partition from the predicted loads).
        if step < iters && reshard_every > 0 && step % reshard_every == 0 {
            let loads: Vec<Vec<f64>> = predictors.iter().map(|p| p.predict()).collect();
            let prev = ShardingPlan { layers: shards.clone() };
            let plan = heterogeneous_sticky(
                topo,
                &loads,
                cons.overlap_degree.min(dims.experts),
                Some(&prev),
            );
            for (old, new) in prev.layers.iter().zip(plan.layers.iter()) {
                for e in 0..dims.experts {
                    if old.holders(e).next() != new.holders(e).next() {
                        experts_moved += 1;
                    }
                }
            }
            shards = plan.layers;
            reshards += 1;
            if reshards == 1 && inject == Some(Injection::DoubleOwn) {
                let owner = shards[0].holders(0).next().expect("chunk 0 has an owner");
                shards[0].add(0, DeviceId((owner.0 + 1) % nd));
            }
        }
    }
    if !violations.is_empty() {
        let shown = violations.len().min(16);
        anyhow::bail!(
            "schedule verification failed: {} violation(s)\n  {}",
            violations.len(),
            violations[..shown].join("\n  ")
        );
    }
    Ok(ScheduleReport {
        ranks: nd,
        layers: nl,
        iters,
        spans,
        reshards,
        experts_moved,
        sends,
        recvs,
        max_frame_bytes: HEADER_LEN + max_floats * 4,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn base(devices: usize, nodes: usize) -> SessionConfig {
        SessionConfig::builder()
            .reference()
            .cluster(nodes, devices)
            .parallel(true)
            .build()
            .unwrap()
    }

    #[test]
    fn default_parallel_config_is_clean() {
        let rep = analyze_config(&base(8, 2), 4, None).unwrap();
        assert_eq!((rep.ranks, rep.layers, rep.iters, rep.spans), (8, 1, 4, 1));
        assert_eq!(rep.reshards, 0);
        assert!(rep.sends > 0 && rep.sends == rep.recvs, "{rep:?}");
    }

    #[test]
    fn overlap_modes_predict_the_same_multiset() {
        let on = SessionConfig::builder()
            .reference()
            .cluster(2, 4)
            .layers(3)
            .parallel(true)
            .overlap(true)
            .build()
            .unwrap();
        let off = SessionConfig::builder()
            .reference()
            .cluster(2, 4)
            .layers(3)
            .parallel(true)
            .overlap(false)
            .build()
            .unwrap();
        let a = analyze_config(&on, 3, None).unwrap();
        let b = analyze_config(&off, 3, None).unwrap();
        assert_eq!((a.sends, a.recvs), (b.sends, b.recvs), "overlap reorders, never adds");
    }

    #[test]
    fn reshard_window_splits_spans_and_moves_experts() {
        let cfg = SessionConfig::builder()
            .reference()
            .cluster(2, 8)
            .layers(2)
            .parallel(true)
            .reshard_every(3)
            .build()
            .unwrap();
        let rep = analyze_config(&cfg, 8, None).unwrap();
        assert_eq!(rep.spans, 3, "8 iters at cadence 3 → spans of 3+3+2");
        assert_eq!(rep.reshards, 2);
    }

    #[test]
    fn injections_are_caught_with_diagnostics() {
        let cfg = base(4, 2);
        let err = analyze_config(&cfg, 2, Some(Injection::DropRecv)).unwrap_err().to_string();
        assert!(err.contains("orphan send"), "{err}");
        let err = analyze_config(&cfg, 2, Some(Injection::SwapBarrier)).unwrap_err().to_string();
        assert!(err.contains("deadlock cycle"), "{err}");
        let err = analyze_config(&cfg, 2, Some(Injection::DoubleOwn)).unwrap_err().to_string();
        assert!(err.contains("must stay an exact partition"), "{err}");
    }

    #[test]
    fn injection_names_parse() {
        assert_eq!(Injection::parse("drop-recv"), Some(Injection::DropRecv));
        assert_eq!(Injection::parse("swap-barrier"), Some(Injection::SwapBarrier));
        assert_eq!(Injection::parse("oversize-frame"), Some(Injection::OversizeFrame));
        assert_eq!(Injection::parse("double-own"), Some(Injection::DoubleOwn));
        assert_eq!(Injection::parse("nope"), None);
    }
}
