//! Criterion-style benchmark harness (the registry snapshot has no
//! `criterion`). Bench targets are declared with `harness = false` in
//! `Cargo.toml` and drive this module directly.
//!
//! Measurement protocol: warmup runs, then `samples` timed batches; reports
//! median ± MAD and throughput. `--bench <filter>` (forwarded by
//! `cargo bench -- <filter>`) selects benchmarks by substring; `--quick`
//! cuts sample counts for smoke runs.

use std::hint::black_box;
use std::time::{Duration, Instant};

use crate::util::stats;

/// Harness configuration, parsed from argv by [`Bench::from_args`].
#[derive(Debug, Clone)]
pub struct Bench {
    pub filter: Option<String>,
    pub warmup: usize,
    pub samples: usize,
    /// Minimum wall time a sample batch should take; iterations auto-scale.
    pub min_sample_time: Duration,
}

impl Default for Bench {
    fn default() -> Self {
        Bench {
            filter: None,
            warmup: 3,
            samples: 15,
            min_sample_time: Duration::from_millis(20),
        }
    }
}

/// One benchmark result, also returned for programmatic use in reports.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub median: Duration,
    pub mad: Duration,
    pub iters_per_sample: u64,
}

impl Bench {
    /// Parse `cargo bench` forwarded args. Unknown flags are ignored so
    /// `cargo bench -- --quick fig09` works.
    pub fn from_args() -> Bench {
        let mut b = Bench::default();
        let args: Vec<String> = std::env::args().skip(1).collect();
        let mut i = 0;
        while i < args.len() {
            match args[i].as_str() {
                "--quick" => {
                    b.warmup = 1;
                    b.samples = 5;
                    b.min_sample_time = Duration::from_millis(5);
                }
                "--samples" if i + 1 < args.len() => {
                    b.samples = args[i + 1].parse().unwrap_or(b.samples);
                    i += 1;
                }
                "--bench" | "--exact" => {} // cargo-internal flags
                s if !s.starts_with("--") => b.filter = Some(s.to_string()),
                _ => {}
            }
            i += 1;
        }
        b
    }

    fn selected(&self, name: &str) -> bool {
        match &self.filter {
            None => true,
            Some(f) => name.contains(f.as_str()),
        }
    }

    /// Time `f`, auto-scaling the iteration count per sample so each sample
    /// batch takes at least `min_sample_time`.
    pub fn run<F: FnMut()>(&self, name: &str, mut f: F) -> Option<BenchResult> {
        if !self.selected(name) {
            return None;
        }
        // Calibrate iterations per sample.
        let mut iters: u64 = 1;
        loop {
            let t0 = Instant::now();
            for _ in 0..iters {
                f();
            }
            let elapsed = t0.elapsed();
            if elapsed >= self.min_sample_time || iters >= 1 << 24 {
                break;
            }
            let scale = (self.min_sample_time.as_secs_f64() / elapsed.as_secs_f64().max(1e-9))
                .ceil()
                .max(2.0);
            iters = (iters as f64 * scale).min((1u64 << 24) as f64) as u64;
        }
        for _ in 0..self.warmup {
            for _ in 0..iters {
                f();
            }
        }
        let mut per_iter = Vec::with_capacity(self.samples);
        for _ in 0..self.samples {
            let t0 = Instant::now();
            for _ in 0..iters {
                f();
            }
            per_iter.push(t0.elapsed().as_secs_f64() / iters as f64);
        }
        let med = stats::median(&per_iter);
        let mad = stats::mad(&per_iter);
        let result = BenchResult {
            name: name.to_string(),
            median: Duration::from_secs_f64(med),
            mad: Duration::from_secs_f64(mad),
            iters_per_sample: iters,
        };
        println!(
            "{:<52} {:>12} ± {:>10}  ({} iters/sample, {} samples)",
            result.name,
            fmt_duration(result.median),
            fmt_duration(result.mad),
            iters,
            self.samples
        );
        Some(result)
    }

    /// Convenience: benchmark a function returning a value (black-boxed).
    pub fn run_val<T, F: FnMut() -> T>(&self, name: &str, mut f: F) -> Option<BenchResult> {
        self.run(name, || {
            black_box(f());
        })
    }

    /// Print a section header (skipped entirely if the filter excludes it).
    pub fn section(&self, title: &str) {
        println!("\n== {title} ==");
    }
}

/// Human-readable duration.
pub fn fmt_duration(d: Duration) -> String {
    let s = d.as_secs_f64();
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.3} µs", s * 1e6)
    } else {
        format!("{:.1} ns", s * 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runs_and_reports() {
        let b = Bench {
            filter: None,
            warmup: 1,
            samples: 3,
            min_sample_time: Duration::from_micros(100),
        };
        let r = b.run("noop", || {}).unwrap();
        assert!(r.iters_per_sample >= 1);
        assert!(r.median.as_secs_f64() < 1.0);
    }

    #[test]
    fn filter_skips() {
        let b = Bench { filter: Some("match".into()), ..Bench::default() };
        assert!(b.run("other", || {}).is_none());
    }

    #[test]
    fn fmt_scales() {
        assert!(fmt_duration(Duration::from_secs(2)).ends_with(" s"));
        assert!(fmt_duration(Duration::from_millis(5)).ends_with(" ms"));
        assert!(fmt_duration(Duration::from_micros(5)).ends_with(" µs"));
        assert!(fmt_duration(Duration::from_nanos(5)).ends_with(" ns"));
    }
}
