//! Expert-load dynamics: a generator reproducing the paper's Figure 3
//! (loads fluctuate and are imbalanced, but drift smoothly between
//! iterations — "temporal locality", §3.2) and the sliding-window load
//! predictor Hecate's scheduler uses (w = 5, §4.2).

pub mod predictor;

pub use predictor::LoadPredictor;

use crate::util::rng::Rng;

/// Generates per-iteration expert load distributions for one MoE layer.
///
/// Model: the gate's affinity for each expert follows a latent log-weight
/// vector that random-walks slowly (smooth drift), initialized from a
/// Dirichlet draw whose concentration controls imbalance; occasional
/// regime shifts re-draw a subset of weights (the sharper changes visible
/// early in training in Figure 3).
#[derive(Debug, Clone)]
pub struct LoadGenerator {
    log_w: Vec<f64>,
    rng: Rng,
    /// Per-iteration random-walk std on log-weights.
    pub drift: f64,
    /// Probability per iteration of a regime shift.
    pub shift_prob: f64,
    /// Fraction of experts re-drawn in a shift.
    pub shift_frac: f64,
}

impl LoadGenerator {
    /// `alpha` is the Dirichlet concentration of the initial distribution —
    /// lower means more skewed loads (Figure 3 shows strong skew; the
    /// paper's §1 measures up to 5.18× straggler slowdown).
    pub fn new(experts: usize, alpha: f64, seed: u64) -> LoadGenerator {
        let mut rng = Rng::new(seed);
        let p = rng.dirichlet(alpha, experts);
        let log_w = p.iter().map(|&x| x.max(1e-12).ln()).collect();
        LoadGenerator { log_w, rng, drift: 0.08, shift_prob: 0.02, shift_frac: 0.2 }
    }

    pub fn num_experts(&self) -> usize {
        self.log_w.len()
    }

    /// Advance one iteration and return the token-fraction per expert
    /// (sums to 1).
    pub fn step(&mut self) -> Vec<f64> {
        // smooth drift
        for w in &mut self.log_w {
            *w += self.rng.normal() * self.drift;
        }
        // occasional sharper regime change
        if self.rng.f64() < self.shift_prob {
            let k = ((self.log_w.len() as f64 * self.shift_frac) as usize).max(1);
            let idx = self.rng.sample_indices(self.log_w.len(), k);
            for i in idx {
                self.log_w[i] += self.rng.normal() * 1.0;
            }
        }
        self.fractions()
    }

    /// Current distribution without advancing.
    pub fn fractions(&self) -> Vec<f64> {
        let max = self.log_w.iter().cloned().fold(f64::MIN, f64::max);
        let exp: Vec<f64> = self.log_w.iter().map(|w| (w - max).exp()).collect();
        let sum: f64 = exp.iter().sum();
        exp.iter().map(|e| e / sum).collect()
    }

    /// Sample integer token counts for `tokens` tokens routed by the gate
    /// this iteration (multinomial around the current fractions — the
    /// stochastic gap between predicted and realized loads that Hecate's
    /// calibration stage handles, §4.2).
    pub fn sample_counts(&mut self, tokens: usize) -> Vec<usize> {
        let f = self.fractions();
        self.rng.multinomial(tokens, &f)
    }
}

/// A full-model load trace: one generator per MoE layer, each with its own
/// skew (Figure 11 shows degrees of imbalance vary strongly across layers).
#[derive(Debug, Clone)]
pub struct ModelLoadTrace {
    pub layers: Vec<LoadGenerator>,
}

impl ModelLoadTrace {
    pub fn new(num_layers: usize, experts: usize, seed: u64) -> ModelLoadTrace {
        let mut meta = Rng::new(seed);
        let layers = (0..num_layers)
            .map(|l| {
                // Layer-dependent skew: alternate strongly- and mildly-skewed
                // layers, matching the per-layer variation in Figure 11.
                let alpha = match l % 4 {
                    0 => 0.08,
                    1 => 0.25,
                    2 => 0.6,
                    _ => 1.5,
                };
                LoadGenerator::new(experts, alpha, meta.next_u64())
            })
            .collect();
        ModelLoadTrace { layers }
    }

    /// Advance all layers one iteration; returns per-layer fractions.
    pub fn step(&mut self) -> Vec<Vec<f64>> {
        self.layers.iter_mut().map(|g| g.step()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats;

    #[test]
    fn fractions_are_distribution() {
        let mut g = LoadGenerator::new(64, 0.1, 7);
        for _ in 0..50 {
            let f = g.step();
            assert_eq!(f.len(), 64);
            assert!((f.iter().sum::<f64>() - 1.0).abs() < 1e-9);
            assert!(f.iter().all(|&x| x >= 0.0));
        }
    }

    #[test]
    fn loads_are_imbalanced_and_fluctuating() {
        let mut g = LoadGenerator::new(64, 0.1, 3);
        let mut stragglers = Vec::new();
        for _ in 0..100 {
            let f = g.step();
            stragglers.push(stats::straggler_factor(&f));
        }
        // Figure 3 / §1: strong imbalance — max expert well above mean.
        assert!(stats::mean(&stragglers) > 3.0, "mean straggler {}", stats::mean(&stragglers));
    }

    #[test]
    fn temporal_locality_consecutive_iterations_similar() {
        // §3.2: load distribution changes smoothly -> consecutive L1
        // distance should be much smaller than distance to a far iteration.
        let mut g = LoadGenerator::new(64, 0.2, 11);
        let mut prev = g.step();
        let first = prev.clone();
        let mut consec = Vec::new();
        for _ in 0..200 {
            let cur = g.step();
            let d: f64 = cur.iter().zip(prev.iter()).map(|(a, b)| (a - b).abs()).sum();
            consec.push(d);
            prev = cur;
        }
        let far: f64 = prev.iter().zip(first.iter()).map(|(a, b)| (a - b).abs()).sum();
        assert!(stats::mean(&consec) < far / 3.0,
            "consecutive drift {} vs long-run {}", stats::mean(&consec), far);
    }

    #[test]
    fn sample_counts_sum() {
        let mut g = LoadGenerator::new(16, 0.5, 5);
        g.step();
        let counts = g.sample_counts(4096);
        assert_eq!(counts.iter().sum::<usize>(), 4096);
    }

    #[test]
    fn per_layer_skew_varies() {
        let mut t = ModelLoadTrace::new(12, 64, 9);
        // settle
        let mut last = Vec::new();
        for _ in 0..20 {
            last = t.step();
        }
        let skews: Vec<f64> = last.iter().map(|f| stats::straggler_factor(f)).collect();
        let max = skews.iter().cloned().fold(f64::MIN, f64::max);
        let min = skews.iter().cloned().fold(f64::MAX, f64::min);
        assert!(max > 2.0 * min, "layer skews should vary: {skews:?}");
    }

    #[test]
    fn deterministic_given_seed() {
        let mut a = LoadGenerator::new(8, 0.3, 42);
        let mut b = LoadGenerator::new(8, 0.3, 42);
        for _ in 0..10 {
            assert_eq!(a.step(), b.step());
        }
    }
}
