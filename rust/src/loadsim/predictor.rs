//! Sliding-window expert-load predictor (§4.2): Hecate estimates the next
//! iteration's load distribution as the average of the latest `w`
//! iterations (the paper uses `w = 5`), relying on the temporal locality of
//! gate decisions.

use std::collections::VecDeque;

/// Per-layer sliding-window average of expert load fractions.
#[derive(Debug, Clone)]
pub struct LoadPredictor {
    window: usize,
    history: VecDeque<Vec<f64>>,
    experts: usize,
}

impl LoadPredictor {
    pub fn new(experts: usize, window: usize) -> LoadPredictor {
        assert!(window >= 1);
        LoadPredictor { window, history: VecDeque::new(), experts }
    }

    /// Record the realized load fractions of an iteration.
    pub fn observe(&mut self, loads: &[f64]) {
        assert_eq!(loads.len(), self.experts);
        if self.history.len() == self.window {
            self.history.pop_front();
        }
        self.history.push_back(loads.to_vec());
    }

    /// Predicted fractions for the next iteration. Uniform until the first
    /// observation (cold start = EP's assumption).
    pub fn predict(&self) -> Vec<f64> {
        if self.history.is_empty() {
            return vec![1.0 / self.experts as f64; self.experts];
        }
        let mut avg = vec![0.0; self.experts];
        for h in &self.history {
            for (a, v) in avg.iter_mut().zip(h.iter()) {
                *a += v;
            }
        }
        let n = self.history.len() as f64;
        for a in &mut avg {
            *a /= n;
        }
        avg
    }

    pub fn observations(&self) -> usize {
        self.history.len()
    }

    pub fn window(&self) -> usize {
        self.window
    }

    pub fn num_experts(&self) -> usize {
        self.experts
    }

    /// Snapshot the sliding window contents, oldest first (checkpointing).
    pub fn history(&self) -> Vec<Vec<f64>> {
        self.history.iter().cloned().collect()
    }

    /// Rebuild a predictor from a [`LoadPredictor::history`] snapshot.
    /// Entries beyond `window` are dropped from the oldest side, mirroring
    /// what repeated `observe` calls would have kept.
    pub fn restore(experts: usize, window: usize, history: Vec<Vec<f64>>) -> LoadPredictor {
        let mut p = LoadPredictor::new(experts, window);
        for h in history {
            p.observe(&h);
        }
        p
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::loadsim::LoadGenerator;
    use crate::util::stats;

    #[test]
    fn cold_start_uniform() {
        let p = LoadPredictor::new(4, 5);
        assert_eq!(p.predict(), vec![0.25; 4]);
    }

    #[test]
    fn window_averages_last_w() {
        let mut p = LoadPredictor::new(2, 2);
        p.observe(&[1.0, 0.0]);
        p.observe(&[0.0, 1.0]);
        assert_eq!(p.predict(), vec![0.5, 0.5]);
        p.observe(&[0.0, 1.0]); // evicts [1,0]
        assert_eq!(p.predict(), vec![0.0, 1.0]);
        assert_eq!(p.observations(), 2);
    }

    #[test]
    fn history_snapshot_restores_predictions() {
        let mut g = LoadGenerator::new(8, 0.3, 5);
        let mut p = LoadPredictor::new(8, 3);
        for _ in 0..7 {
            p.observe(&g.step());
        }
        let r = LoadPredictor::restore(8, p.window(), p.history());
        assert_eq!(r.observations(), p.observations());
        assert_eq!(r.predict(), p.predict());
        assert_eq!(r.num_experts(), 8);
    }

    #[test]
    fn predictor_beats_uniform_on_smooth_trace() {
        // The whole premise of §3.2: with temporal locality, a sliding
        // window predicts the next distribution far better than uniform.
        let mut g = LoadGenerator::new(32, 0.15, 21);
        let mut p = LoadPredictor::new(32, 5);
        let mut err_pred = Vec::new();
        let mut err_unif = Vec::new();
        for _ in 0..10 {
            p.observe(&g.step());
        }
        for _ in 0..200 {
            let pred = p.predict();
            let actual = g.step();
            err_pred.push(
                pred.iter().zip(actual.iter()).map(|(a, b)| (a - b).abs()).sum::<f64>(),
            );
            let u = 1.0 / 32.0;
            err_unif.push(actual.iter().map(|b| (u - b).abs()).sum::<f64>());
            p.observe(&actual);
        }
        assert!(
            stats::mean(&err_pred) < 0.4 * stats::mean(&err_unif),
            "pred {} vs uniform {}",
            stats::mean(&err_pred),
            stats::mean(&err_unif)
        );
    }
}
