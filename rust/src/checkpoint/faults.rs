//! Fault injection + recovery cost model for the cluster simulator.
//!
//! The simulator kills one device at a chosen step, restarts, and replays
//! from the last snapshot. Recovery wall-clock decomposes as
//!
//! ```text
//! MTTR = detect + restore_io + redistribute + replay
//! ```
//!
//! * `detect` — failure detection / coordinator re-election (a constant,
//!   dominated by heartbeat timeouts, default 5 s);
//! * `restore_io` — surviving ranks re-read the checkpoint shards from
//!   shared storage in parallel;
//! * `redistribute` — the dead rank's shard must reach its new owners over
//!   the inter-node fabric (a re-shard, priced like the spAG traffic the
//!   elastic planner produces);
//! * `replay` — iterations since the last snapshot re-run at steady-state
//!   speed.
//!
//! Steady state pays the amortized snapshot cost `checkpoint_time /
//! interval` per iteration — the classic Young/Daly trade the recovery
//! table in `sim/report.rs` sweeps.

use crate::config::ModelConfig;
use crate::topology::Topology;

/// Fault-injection scenario knobs.
#[derive(Debug, Clone, Copy)]
pub struct FaultSpec {
    /// Iteration at which one device dies.
    pub fail_step: usize,
    /// Which device dies (bounded by the topology at use sites).
    pub fail_device: usize,
    /// Snapshot interval in iterations (0 = checkpointing disabled).
    pub checkpoint_every: usize,
    /// Failure-detection time, seconds.
    pub detect_time: f64,
    /// Per-device checkpoint read/write bandwidth to shared storage,
    /// bytes/s.
    pub disk_bw: f64,
}

impl Default for FaultSpec {
    fn default() -> Self {
        FaultSpec {
            fail_step: 50,
            fail_device: 0,
            checkpoint_every: 25,
            detect_time: 5.0,
            disk_bw: 2e9,
        }
    }
}

/// Cost breakdown of one failure + recovery.
#[derive(Debug, Clone, Copy, Default)]
pub struct RecoveryStats {
    /// Durable bytes per snapshot (global copy of MoE params + opt state).
    pub checkpoint_bytes: f64,
    /// Wall time of one snapshot (parallel per-rank writes).
    pub checkpoint_time: f64,
    /// Amortized per-iteration snapshot overhead in steady state.
    pub steady_overhead: f64,
    pub detect: f64,
    pub restore_io: f64,
    pub redistribute: f64,
    /// Iterations lost since the last snapshot.
    pub replay_iters: usize,
    pub replay: f64,
    /// detect + restore_io + redistribute + replay.
    pub mttr: f64,
}

/// Durable checkpoint bytes of one model: the sharded MoE expert parameters
/// plus their optimizer state — the *single global copy* FSSDP maintains
/// (§3.2). Dense/attention state is DP-replicated and dominated by this.
pub fn checkpoint_bytes(model: &ModelConfig) -> f64 {
    let per_expert =
        model.expert_bytes() as f64 + (model.expert_params() * model.opt_bytes_per_param) as f64;
    (model.layers * model.experts) as f64 * per_expert
}

/// Price a failure at `spec.fail_step` given the steady-state iteration
/// time. Pure cost model — the numeric replay equivalence is proven
/// separately by `rust/tests/checkpoint_resume.rs`.
pub fn recover(
    topo: &Topology,
    model: &ModelConfig,
    iter_time: f64,
    spec: &FaultSpec,
) -> RecoveryStats {
    let world = topo.num_devices().max(1) as f64;
    let bytes = checkpoint_bytes(model);

    // A snapshot exists at failure time only if at least one interval
    // completed before the failing step.
    let has_snapshot = spec.checkpoint_every > 0 && spec.fail_step >= spec.checkpoint_every;
    let (checkpoint_time, steady_overhead) = if spec.checkpoint_every == 0 {
        (0.0, 0.0)
    } else {
        let t = bytes / (world * spec.disk_bw) + 1e-3; // + manifest write
        (t, t / spec.checkpoint_every as f64)
    };
    let replay_iters = if has_snapshot {
        spec.fail_step % spec.checkpoint_every
    } else {
        // No snapshot yet (checkpointing off, or failure before the first
        // interval): everything since step 0 replays.
        spec.fail_step
    };

    let survivors = (world - 1.0).max(1.0);
    // Without a written snapshot there is nothing durable to read or
    // redistribute: the run re-initializes from scratch and replays.
    let (restore_io, redistribute) = if !has_snapshot {
        (0.0, 0.0)
    } else {
        (
            bytes / (survivors * spec.disk_bw),
            // The dead rank's shard share crosses the inter-node fabric once
            // the elastic planner re-assigns it (priced like one spAG of
            // that volume).
            topo.inter_lat + (bytes / world) / topo.inter_bw,
        )
    };
    let replay = replay_iters as f64 * iter_time;
    let detect = spec.detect_time;
    RecoveryStats {
        checkpoint_bytes: bytes,
        checkpoint_time,
        steady_overhead,
        detect,
        restore_io,
        redistribute,
        replay_iters,
        replay,
        mttr: detect + restore_io + redistribute + replay,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn setup() -> (Topology, ModelConfig) {
        (Topology::cluster_a(2, 4), ModelConfig::preset("gpt-moe-s").unwrap().with_experts(16))
    }

    #[test]
    fn replay_follows_snapshot_cadence() {
        let (topo, model) = setup();
        let spec = FaultSpec { fail_step: 57, checkpoint_every: 25, ..Default::default() };
        let r = recover(&topo, &model, 0.1, &spec);
        assert_eq!(r.replay_iters, 57 % 25);
        assert!((r.replay - (57 % 25) as f64 * 0.1).abs() < 1e-12);
        assert!(r.mttr >= r.detect + r.replay);
    }

    #[test]
    fn no_checkpoint_replays_from_scratch() {
        let (topo, model) = setup();
        let spec = FaultSpec { fail_step: 80, checkpoint_every: 0, ..Default::default() };
        let r = recover(&topo, &model, 0.1, &spec);
        assert_eq!(r.replay_iters, 80);
        assert_eq!(r.steady_overhead, 0.0);
        let with = recover(
            &topo,
            &model,
            0.1,
            &FaultSpec { fail_step: 80, checkpoint_every: 10, ..Default::default() },
        );
        assert!(with.mttr < r.mttr, "checkpointing must cut MTTR");
        assert!(with.steady_overhead > 0.0, "…at a steady-state cost");
    }

    #[test]
    fn failure_before_first_snapshot_replays_from_scratch() {
        // every=25 but failing at step 5: no snapshot exists yet, so there
        // is nothing to restore — replay everything, read nothing.
        let (topo, model) = setup();
        let spec = FaultSpec { fail_step: 5, checkpoint_every: 25, ..Default::default() };
        let r = recover(&topo, &model, 0.1, &spec);
        assert_eq!(r.replay_iters, 5);
        assert_eq!(r.restore_io, 0.0);
        assert_eq!(r.redistribute, 0.0);
        // snapshots are still scheduled, so steady overhead is nonzero
        assert!(r.steady_overhead > 0.0);
    }

    #[test]
    fn tighter_interval_costs_more_overhead() {
        let (topo, model) = setup();
        let every = |n: usize| {
            recover(
                &topo,
                &model,
                0.1,
                &FaultSpec { fail_step: 99, checkpoint_every: n, ..Default::default() },
            )
        };
        assert!(every(10).steady_overhead > every(100).steady_overhead);
        // same snapshot size regardless of cadence
        assert_eq!(every(10).checkpoint_bytes, every(100).checkpoint_bytes);
        assert!(every(10).checkpoint_bytes > 0.0);
    }

    #[test]
    fn bytes_scale_with_model() {
        let small = ModelConfig::preset("tiny").unwrap();
        let big = ModelConfig::preset("gpt-moe-s").unwrap();
        assert!(checkpoint_bytes(&big) > checkpoint_bytes(&small) * 10.0);
    }
}
