//! Binary on-disk format of checkpoint blobs.
//!
//! Every blob (the global-state file and each per-rank shard file) is:
//!
//! ```text
//! [magic "HCKP"] [version u8] [payload ...] [fnv1a64(header+payload) u64 LE]
//! ```
//!
//! The payload is a flat little-endian stream written/read by [`Writer`] /
//! [`Reader`]: scalars as fixed-width LE integers, slices length-prefixed
//! with a `u64` count, floats as IEEE-754 bit patterns. There is no
//! self-description — the schema is fixed per format [`VERSION`] and
//! documented in `DESIGN.md §Checkpoint format`; bumping the schema means
//! bumping the version byte, and readers reject unknown versions up front
//! (the SNIPPETS.md snapshot idiom, minus serde).

/// Magic prefix of every checkpoint blob.
pub const MAGIC: [u8; 4] = *b"HCKP";

/// Current format version. Readers accept exactly this version.
///
/// * v1 — single-layer payloads (one implicit MoE layer per blob).
/// * v2 — multi-layer: the global blob carries a layer-count header and one
///   section per layer (gate weights + predictor window); each rank blob
///   carries one expert-shard section per layer. See `DESIGN.md §Checkpoint
///   format v2`.
pub const VERSION: u8 = 2;

/// FNV-1a 64-bit hash, used as the integrity trailer of every blob.
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xCBF29CE484222325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001B3);
    }
    h
}

/// Append-only blob writer. `finish()` seals the blob with the checksum.
#[derive(Debug, Clone)]
pub struct Writer {
    buf: Vec<u8>,
}

impl Default for Writer {
    fn default() -> Self {
        Writer::new()
    }
}

impl Writer {
    pub fn new() -> Writer {
        let mut buf = Vec::with_capacity(64);
        buf.extend_from_slice(&MAGIC);
        buf.push(VERSION);
        Writer { buf }
    }

    pub fn put_u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    pub fn put_u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn put_u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn put_usize(&mut self, v: usize) {
        self.put_u64(v as u64);
    }

    pub fn put_f32s(&mut self, v: &[f32]) {
        self.put_u64(v.len() as u64);
        for &x in v {
            self.buf.extend_from_slice(&x.to_le_bytes());
        }
    }

    pub fn put_f64s(&mut self, v: &[f64]) {
        self.put_u64(v.len() as u64);
        for &x in v {
            self.buf.extend_from_slice(&x.to_le_bytes());
        }
    }

    pub fn put_i32s(&mut self, v: &[i32]) {
        self.put_u64(v.len() as u64);
        for &x in v {
            self.buf.extend_from_slice(&x.to_le_bytes());
        }
    }

    pub fn put_usizes(&mut self, v: &[usize]) {
        self.put_u64(v.len() as u64);
        for &x in v {
            self.put_u64(x as u64);
        }
    }

    /// Seal the blob: appends the checksum over everything written so far
    /// (including magic + version) and returns the bytes.
    pub fn finish(mut self) -> Vec<u8> {
        let sum = fnv1a64(&self.buf);
        self.buf.extend_from_slice(&sum.to_le_bytes());
        self.buf
    }

    /// Payload bytes written so far (excluding header), for size reporting.
    pub fn payload_len(&self) -> usize {
        self.buf.len().saturating_sub(MAGIC.len() + 1)
    }
}

/// Sequential blob reader. [`Reader::open`] validates magic, version, and
/// checksum before any field is consumed.
#[derive(Debug)]
pub struct Reader<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    pub fn open(bytes: &'a [u8]) -> anyhow::Result<Reader<'a>> {
        anyhow::ensure!(
            bytes.len() >= MAGIC.len() + 1 + 8,
            "checkpoint blob truncated ({} bytes)",
            bytes.len()
        );
        anyhow::ensure!(
            bytes[..MAGIC.len()] == MAGIC,
            "not a hecate checkpoint blob (bad magic)"
        );
        let version = bytes[MAGIC.len()];
        anyhow::ensure!(
            version != 1,
            "checkpoint blob is format v1 (single-layer engine); this build reads v{VERSION} \
             (multi-layer) and cannot migrate v1 blobs — re-create the checkpoint by \
             re-running training, or load it with a pre-v2 build"
        );
        anyhow::ensure!(
            version == VERSION,
            "unsupported checkpoint format version {version} (this build reads v{VERSION})"
        );
        let (body, trailer) = bytes.split_at(bytes.len() - 8);
        let stored = u64::from_le_bytes(trailer.try_into().expect("8-byte trailer"));
        let actual = fnv1a64(body);
        anyhow::ensure!(
            stored == actual,
            "checkpoint blob corrupt: checksum {actual:#018x} != stored {stored:#018x}"
        );
        Ok(Reader { b: body, pos: MAGIC.len() + 1 })
    }

    fn take(&mut self, n: usize) -> anyhow::Result<&'a [u8]> {
        anyhow::ensure!(
            self.pos + n <= self.b.len(),
            "checkpoint blob underrun: need {n} bytes at offset {}",
            self.pos
        );
        let s = &self.b[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    pub fn take_u8(&mut self) -> anyhow::Result<u8> {
        Ok(self.take(1)?[0])
    }

    pub fn take_u32(&mut self) -> anyhow::Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().expect("4 bytes")))
    }

    pub fn take_u64(&mut self) -> anyhow::Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().expect("8 bytes")))
    }

    pub fn take_usize(&mut self) -> anyhow::Result<usize> {
        Ok(self.take_u64()? as usize)
    }

    fn take_len(&mut self) -> anyhow::Result<usize> {
        let n = self.take_u64()? as usize;
        // A length can never exceed the bytes that remain — reject early so
        // a corrupt length cannot trigger a huge allocation.
        anyhow::ensure!(
            n <= self.b.len() - self.pos,
            "checkpoint blob corrupt: implausible element count {n}"
        );
        Ok(n)
    }

    pub fn take_f32s(&mut self) -> anyhow::Result<Vec<f32>> {
        let n = self.take_len()?;
        let raw = self.take(n * 4)?;
        Ok(raw
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().expect("4 bytes")))
            .collect())
    }

    pub fn take_f64s(&mut self) -> anyhow::Result<Vec<f64>> {
        let n = self.take_len()?;
        let raw = self.take(n * 8)?;
        Ok(raw
            .chunks_exact(8)
            .map(|c| f64::from_le_bytes(c.try_into().expect("8 bytes")))
            .collect())
    }

    pub fn take_i32s(&mut self) -> anyhow::Result<Vec<i32>> {
        let n = self.take_len()?;
        let raw = self.take(n * 4)?;
        Ok(raw
            .chunks_exact(4)
            .map(|c| i32::from_le_bytes(c.try_into().expect("4 bytes")))
            .collect())
    }

    pub fn take_usizes(&mut self) -> anyhow::Result<Vec<usize>> {
        let n = self.take_len()?;
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            out.push(self.take_u64()? as usize);
        }
        Ok(out)
    }

    /// Assert the whole payload was consumed (schema drift detector).
    pub fn done(&self) -> anyhow::Result<()> {
        anyhow::ensure!(
            self.pos == self.b.len(),
            "checkpoint blob has {} trailing bytes (schema mismatch?)",
            self.b.len() - self.pos
        );
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_all_field_kinds() {
        let mut w = Writer::new();
        w.put_u8(7);
        w.put_u32(0xDEADBEEF);
        w.put_u64(u64::MAX - 1);
        w.put_usize(42);
        w.put_f32s(&[1.5, -2.25, f32::MIN_POSITIVE]);
        w.put_f64s(&[0.1, -1e300]);
        w.put_i32s(&[-1, 0, i32::MAX]);
        w.put_usizes(&[3, 1, 4, 1, 5]);
        let bytes = w.finish();

        let mut r = Reader::open(&bytes).unwrap();
        assert_eq!(r.take_u8().unwrap(), 7);
        assert_eq!(r.take_u32().unwrap(), 0xDEADBEEF);
        assert_eq!(r.take_u64().unwrap(), u64::MAX - 1);
        assert_eq!(r.take_usize().unwrap(), 42);
        assert_eq!(r.take_f32s().unwrap(), vec![1.5, -2.25, f32::MIN_POSITIVE]);
        assert_eq!(r.take_f64s().unwrap(), vec![0.1, -1e300]);
        assert_eq!(r.take_i32s().unwrap(), vec![-1, 0, i32::MAX]);
        assert_eq!(r.take_usizes().unwrap(), vec![3, 1, 4, 1, 5]);
        r.done().unwrap();
    }

    #[test]
    fn float_bits_survive_exactly() {
        // Checkpoints must be bit-exact: NaN payloads, -0.0, subnormals.
        let vals = [f32::NAN, -0.0, 1e-40, f32::INFINITY, -f32::INFINITY];
        let mut w = Writer::new();
        w.put_f32s(&vals);
        let bytes = w.finish();
        let mut r = Reader::open(&bytes).unwrap();
        let back = r.take_f32s().unwrap();
        for (a, b) in vals.iter().zip(back.iter()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn corruption_detected() {
        let mut w = Writer::new();
        w.put_f32s(&[1.0, 2.0, 3.0]);
        let mut bytes = w.finish();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0x40;
        let err = Reader::open(&bytes).unwrap_err().to_string();
        assert!(err.contains("corrupt"), "{err}");
    }

    #[test]
    fn version_and_magic_rejected() {
        let mut w = Writer::new();
        w.put_u8(1);
        let good = w.finish();

        let mut wrong_magic = good.clone();
        wrong_magic[0] = b'X';
        assert!(Reader::open(&wrong_magic).unwrap_err().to_string().contains("magic"));

        // Future version: patch the byte and re-seal with a valid checksum.
        let mut future = good.clone();
        future[4] = VERSION + 1;
        let body_len = future.len() - 8;
        let sum = fnv1a64(&future[..body_len]);
        future[body_len..].copy_from_slice(&sum.to_le_bytes());
        let err = Reader::open(&future).unwrap_err().to_string();
        assert!(err.contains("version"), "{err}");

        assert!(Reader::open(b"HC").is_err());
    }

    #[test]
    fn v1_blob_gets_migration_error() {
        // A v1 (single-layer) blob must be rejected with a message that
        // names the v1 → v2 format change, not a generic version error.
        let mut w = Writer::new();
        w.put_u64(7);
        let mut v1 = w.finish();
        v1[4] = 1;
        let body_len = v1.len() - 8;
        let sum = fnv1a64(&v1[..body_len]);
        v1[body_len..].copy_from_slice(&sum.to_le_bytes());
        let err = Reader::open(&v1).unwrap_err().to_string();
        assert!(err.contains("v1") && err.contains("single-layer"), "{err}");
    }

    #[test]
    fn trailing_bytes_and_underrun_detected() {
        let mut w = Writer::new();
        w.put_u64(5);
        let bytes = w.finish();
        let mut r = Reader::open(&bytes).unwrap();
        assert!(r.done().is_err()); // nothing consumed yet
        assert_eq!(r.take_u64().unwrap(), 5);
        r.done().unwrap();
        assert!(r.take_u8().is_err()); // past the end

        // implausible length prefix
        let mut w = Writer::new();
        w.put_u64(u64::MAX / 2);
        let bytes = w.finish();
        let mut r = Reader::open(&bytes).unwrap();
        assert!(r.take_f32s().is_err());
    }
}
