//! Sharded checkpointing + elastic resume for FSSDP training.
//!
//! FSSDP's durable training state is *exactly the shard set*: expert
//! parameter chunks and Adam moments live on their owner rank only (one
//! global copy, §3.2), everything else (load-predictor windows, RNG streams,
//! step counter, gate weights) is small replicated metadata. The engine is
//! multi-layer (format v2), so a checkpoint is:
//!
//! * one **manifest** (`manifest.json`, written through
//!   [`crate::util::json`] — no serde in the offline registry),
//! * one **global blob** (`global.bin`) with the replicated metadata: a
//!   layer-count header plus one per-layer section (gate weights +
//!   predictor window),
//! * one **shard blob per rank** (`rank-<r>.bin`) with one per-layer
//!   section holding the expert states that rank owns in that layer.
//!
//! All blobs use the version-byte-prefixed binary format of
//! [`format`](crate::checkpoint::format)
//! (magic + version + FNV-64 integrity trailer; see `DESIGN.md §Checkpoint
//! format v2`). v1 (single-layer) blobs are rejected with a clear migration
//! error.
//!
//! The headline capability is **elastic resume** ([`reshard`]):
//! [`crate::fssdp::Session::resume`] accepts a topology with a
//! *different* device count than the one that wrote the checkpoint. The
//! resharding planner re-runs the heterogeneous sharding algorithm
//! ([`crate::sharding`], jointly over all layers) over the restored load
//! statistics to lay the chunks out on the new world — and because FSSDP
//! placement freedom never changes the math, an N-device run resumes on M
//! devices with numerically identical training
//! (`rust/tests/checkpoint_resume.rs`).
//!
//! [`faults`] adds the failure model the simulator uses to report
//! recovery-time/MTTR tables (`hecate simulate --fail-step …`).

pub mod faults;
pub mod format;
pub mod reshard;
pub mod shard;

pub use reshard::ReshardPlan;

use std::path::{Path, PathBuf};

use crate::fssdp::LayerDims;
use crate::topology::Topology;
use crate::util::json::{obj, Json};

/// Durable state of one expert: parameter chunk + Adam moments.
#[derive(Debug, Clone, PartialEq)]
pub struct ExpertState {
    pub chunk: Vec<f32>,
    pub m: Vec<f32>,
    pub v: Vec<f32>,
    pub t: u32,
}

/// Durable state of one MoE layer.
///
/// `experts[e]` is the single global copy of expert `e`'s durable state;
/// `owners[e]` records which rank held it when the snapshot was taken (used
/// for zero-movement restore at the same world size, and for move
/// accounting when resharding to a different world).
#[derive(Debug, Clone)]
pub struct LayerCkpt {
    pub owners: Vec<usize>,
    pub experts: Vec<ExpertState>,
    /// This layer's gate weights (replicated dense DP state; frozen).
    pub gate_w: Vec<f32>,
    /// This layer's sliding-window load history, oldest first.
    pub predictor_history: Vec<Vec<f64>>,
}

/// Complete training state of the numeric FSSDP engine at a step boundary.
#[derive(Debug, Clone)]
pub struct TrainState {
    /// Next iteration to run (iterations `0..step` are already applied).
    pub step: u64,
    /// Per-layer dimensions (all MoE layers share one shape).
    pub dims: LayerDims,
    /// Engine construction seed (data streams are keyed on it).
    pub seed: u64,
    /// Logical data-shard count of the run. Fixed for the lifetime of a
    /// training job — elastic resume changes the *device* count, never the
    /// data stream.
    pub data_shards: usize,
    /// One entry per MoE layer, in layer order.
    pub layers: Vec<LayerCkpt>,
    pub predictor_window: usize,
    pub rng_state: [u64; 4],
    pub mem_slots: usize,
    pub overlap_degree: usize,
    /// Algorithm 2 re-sharding interval of the run (0 = never).
    pub reshard_every: usize,
}

impl TrainState {
    pub fn num_layers(&self) -> usize {
        self.layers.len()
    }
}

/// Topology recorded in a checkpoint manifest.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SavedTopo {
    pub nodes: usize,
    pub devices_per_node: usize,
}

impl SavedTopo {
    pub fn world(&self) -> usize {
        self.nodes * self.devices_per_node
    }
}

/// Result of a [`save`]: what landed on disk.
#[derive(Debug, Clone)]
pub struct CheckpointInfo {
    pub dir: PathBuf,
    pub files: usize,
    pub total_bytes: usize,
}

fn rank_file(r: usize) -> String {
    format!("rank-{r}.bin")
}

/// Write a checkpoint of `state` (taken on `topo`) into `dir`.
///
/// Layout: `manifest.json` + `global.bin` + one `rank-<r>.bin` per device,
/// each rank blob holding, per layer, exactly the experts that layer's
/// `owners` assigns to it. Ranks that own nothing still get an (empty) blob
/// so the manifest's rank list always matches the world size.
pub fn save(dir: &Path, state: &TrainState, topo: &Topology) -> anyhow::Result<CheckpointInfo> {
    let world = topo.num_devices();
    anyhow::ensure!(!state.layers.is_empty(), "state holds no layers");
    for (l, layer) in state.layers.iter().enumerate() {
        anyhow::ensure!(
            layer.experts.len() == layer.owners.len(),
            "layer {l} has {} experts but {} owner entries",
            layer.experts.len(),
            layer.owners.len()
        );
        anyhow::ensure!(
            layer.experts.len() == state.dims.experts,
            "layer {l} holds {} experts, dims say {}",
            layer.experts.len(),
            state.dims.experts
        );
        for (e, &o) in layer.owners.iter().enumerate() {
            anyhow::ensure!(
                o < world,
                "layer {l} expert {e} owned by rank {o} outside world {world}"
            );
        }
    }
    std::fs::create_dir_all(dir)?;

    let mut files = 0usize;
    let mut total_bytes = 0usize;
    let mut rank_entries: Vec<Json> = Vec::with_capacity(world);

    for r in 0..world {
        let expert_ids: Vec<Vec<usize>> = state
            .layers
            .iter()
            .map(|layer| {
                (0..layer.experts.len()).filter(|&e| layer.owners[e] == r).collect()
            })
            .collect();
        let count: usize = expert_ids.iter().map(|ids| ids.len()).sum();
        let bytes = shard::encode_rank(state, r, &expert_ids);
        let sum = format::fnv1a64(&bytes);
        let name = rank_file(r);
        std::fs::write(dir.join(&name), &bytes)?;
        total_bytes += bytes.len();
        files += 1;
        rank_entries.push(obj([
            ("rank", r.into()),
            ("file", name.as_str().into()),
            ("expert_states", count.into()),
            ("bytes", bytes.len().into()),
            ("fnv", format!("{sum:#018x}").as_str().into()),
        ]));
    }

    let global = shard::encode_global(state);
    let global_sum = format::fnv1a64(&global);
    std::fs::write(dir.join("global.bin"), &global)?;
    total_bytes += global.len();
    files += 1;

    // Remove stale shard files left by a previous save with a larger world
    // (elastic restarts shrink the rank set; load() is manifest-driven, but
    // stale rank blobs would misrepresent the directory and leak bytes).
    let mut stale = world;
    while dir.join(rank_file(stale)).exists() {
        std::fs::remove_file(dir.join(rank_file(stale)))?;
        stale += 1;
    }

    let manifest = obj([
        ("format", "hecate-checkpoint".into()),
        ("version", (format::VERSION as usize).into()),
        ("step", (state.step as usize).into()),
        ("world", world.into()),
        ("nodes", topo.nodes.into()),
        ("devices_per_node", topo.devices_per_node.into()),
        ("layers", state.layers.len().into()),
        ("experts", state.dims.experts.into()),
        ("chunk_len", state.dims.chunk_len().into()),
        ("global_file", "global.bin".into()),
        ("global_fnv", format!("{global_sum:#018x}").as_str().into()),
        ("ranks", Json::Arr(rank_entries)),
    ]);
    let text = manifest.to_string_pretty();
    std::fs::write(dir.join("manifest.json"), &text)?;
    total_bytes += text.len();
    files += 1;

    crate::log_info!(
        "checkpoint: step {} -> {} ({} layers, {} files, {:.2} MB)",
        state.step,
        dir.display(),
        state.layers.len(),
        files,
        total_bytes as f64 / 1e6
    );
    Ok(CheckpointInfo { dir: dir.to_path_buf(), files, total_bytes })
}

fn parse_hex_fnv(j: &Json, key: &str) -> anyhow::Result<u64> {
    let s = j
        .req(key)?
        .as_str()
        .ok_or_else(|| anyhow::anyhow!("manifest `{key}` must be a string"))?;
    let hex = s.strip_prefix("0x").unwrap_or(s);
    u64::from_str_radix(hex, 16).map_err(|_| anyhow::anyhow!("manifest `{key}`: bad hex `{s}`"))
}

fn req_usize(j: &Json, key: &str) -> anyhow::Result<usize> {
    j.req(key)?
        .as_usize()
        .ok_or_else(|| anyhow::anyhow!("manifest `{key}` must be a non-negative integer"))
}

/// Read a checkpoint written by [`save`]. Verifies the manifest schema,
/// every blob's magic/version/checksum, and that the shard set is complete
/// (every layer's every expert restored exactly once).
pub fn load(dir: &Path) -> anyhow::Result<(TrainState, SavedTopo)> {
    let manifest_path = dir.join("manifest.json");
    let text = std::fs::read_to_string(&manifest_path).map_err(|e| {
        anyhow::anyhow!("cannot read checkpoint manifest {}: {e}", manifest_path.display())
    })?;
    let manifest =
        Json::parse(&text).map_err(|e| anyhow::anyhow!("checkpoint manifest: {e}"))?;

    let fmt = manifest.req("format")?.as_str().unwrap_or("");
    anyhow::ensure!(fmt == "hecate-checkpoint", "not a hecate checkpoint manifest (`{fmt}`)");
    let version = req_usize(&manifest, "version")?;
    anyhow::ensure!(
        version != 1,
        "checkpoint manifest is format v1 (single-layer engine); this build reads v{} \
         (multi-layer) — re-create the checkpoint, or load it with a pre-v2 build",
        format::VERSION
    );
    anyhow::ensure!(
        version == format::VERSION as usize,
        "unsupported checkpoint version {version} (this build reads v{})",
        format::VERSION
    );
    let world = req_usize(&manifest, "world")?;
    let saved = SavedTopo {
        nodes: req_usize(&manifest, "nodes")?,
        devices_per_node: req_usize(&manifest, "devices_per_node")?,
    };
    anyhow::ensure!(
        saved.world() == world && world > 0,
        "manifest world {world} inconsistent with {} nodes x {} devices",
        saved.nodes,
        saved.devices_per_node
    );
    let num_layers = req_usize(&manifest, "layers")?;
    let num_experts = req_usize(&manifest, "experts")?;
    let chunk_len = req_usize(&manifest, "chunk_len")?;

    // ---- global blob ----
    let global_name = manifest.req("global_file")?.as_str().unwrap_or("global.bin").to_string();
    let global_bytes = std::fs::read(dir.join(&global_name))?;
    anyhow::ensure!(
        format::fnv1a64(&global_bytes) == parse_hex_fnv(&manifest, "global_fnv")?,
        "{global_name}: content does not match manifest checksum"
    );
    let mut state = shard::decode_global(&global_bytes)?;
    anyhow::ensure!(
        state.layers.len() == num_layers,
        "global blob has {} layers, manifest says {num_layers}",
        state.layers.len()
    );
    anyhow::ensure!(
        state.dims.experts == num_experts,
        "global blob has {} experts, manifest says {num_experts}",
        state.dims.experts
    );
    anyhow::ensure!(
        state.dims.chunk_len() == chunk_len,
        "global blob chunk_len {} != manifest {chunk_len}",
        state.dims.chunk_len()
    );
    anyhow::ensure!(
        manifest.req("step")?.as_usize() == Some(state.step as usize),
        "manifest step does not match global blob"
    );

    // ---- rank shard blobs ----
    let ranks = manifest
        .req("ranks")?
        .as_arr()
        .ok_or_else(|| anyhow::anyhow!("manifest `ranks` must be an array"))?;
    anyhow::ensure!(ranks.len() == world, "manifest lists {} ranks, world is {world}", ranks.len());

    let mut experts: Vec<Vec<Option<ExpertState>>> =
        (0..num_layers).map(|_| (0..num_experts).map(|_| None).collect()).collect();
    let mut owners = vec![vec![usize::MAX; num_experts]; num_layers];
    for entry in ranks {
        let r = req_usize(entry, "rank")?;
        anyhow::ensure!(r < world, "manifest rank {r} outside world {world}");
        let file = entry
            .req("file")?
            .as_str()
            .ok_or_else(|| anyhow::anyhow!("manifest rank {r}: `file` must be a string"))?;
        let bytes = std::fs::read(dir.join(file))?;
        anyhow::ensure!(
            format::fnv1a64(&bytes) == parse_hex_fnv(entry, "fnv")?,
            "{file}: content does not match manifest checksum"
        );
        let decoded = shard::decode_rank(&bytes, chunk_len, num_layers)?;
        anyhow::ensure!(
            decoded.rank == r,
            "{file}: blob is for rank {}, expected {r}",
            decoded.rank
        );
        for (l, layer) in decoded.layers.into_iter().enumerate() {
            for (e, st) in layer {
                anyhow::ensure!(e < num_experts, "{file}: layer {l} expert id {e} out of range");
                anyhow::ensure!(
                    experts[l][e].is_none(),
                    "layer {l} expert {e} appears in multiple rank shards (ranks {} and {r})",
                    owners[l][e]
                );
                experts[l][e] = Some(st);
                owners[l][e] = r;
            }
        }
    }
    for (l, (layer_experts, layer_owners)) in
        experts.into_iter().zip(owners.into_iter()).enumerate()
    {
        let mut restored = Vec::with_capacity(num_experts);
        for (e, st) in layer_experts.into_iter().enumerate() {
            restored.push(st.ok_or_else(|| {
                anyhow::anyhow!("layer {l} expert {e} missing from every rank shard")
            })?);
        }
        state.layers[l].experts = restored;
        state.layers[l].owners = layer_owners;
    }

    crate::log_info!(
        "checkpoint: loaded step {} from {} ({} layers x {} experts over {} ranks)",
        state.step,
        dir.display(),
        num_layers,
        num_experts,
        world
    );
    Ok((state, saved))
}

#[cfg(test)]
pub(crate) fn test_state_layers(
    experts: usize,
    world: usize,
    num_layers: usize,
    seed: u64,
) -> TrainState {
    use crate::util::rng::Rng;
    let dims = LayerDims { tokens: 16, d_model: 8, d_ffn: 16, experts, cap: 16 };
    let mut rng = Rng::new(seed);
    let cl = dims.chunk_len();
    let mk = |rng: &mut Rng| -> Vec<f32> { (0..cl).map(|_| rng.normal() as f32).collect() };
    let layers: Vec<LayerCkpt> = (0..num_layers)
        .map(|l| LayerCkpt {
            owners: (0..experts).map(|e| (e + l) % world).collect(),
            experts: (0..experts)
                .map(|_| ExpertState {
                    chunk: mk(&mut rng),
                    m: mk(&mut rng),
                    v: mk(&mut rng),
                    t: 3,
                })
                .collect(),
            gate_w: (0..dims.d_model * experts).map(|_| rng.normal() as f32).collect(),
            predictor_history: (0..3).map(|_| rng.dirichlet(0.5, experts)).collect(),
        })
        .collect();
    TrainState {
        step: 7,
        dims,
        seed,
        data_shards: world,
        layers,
        predictor_window: 5,
        rng_state: [1, 2, 3, 4],
        mem_slots: 4,
        overlap_degree: 4,
        reshard_every: 0,
    }
}

#[cfg(test)]
pub(crate) fn test_state(experts: usize, world: usize, seed: u64) -> TrainState {
    test_state_layers(experts, world, 1, seed)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::assert_allclose;

    fn tmpdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir()
            .join(format!("hecate-ckpt-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn save_load_roundtrip() {
        let dir = tmpdir("roundtrip");
        let topo = Topology::cluster_a(2, 2);
        let state = test_state_layers(10, 4, 3, 42);
        let info = save(&dir, &state, &topo).unwrap();
        assert_eq!(info.files, 4 + 1 + 1, "4 rank blobs + global + manifest");

        let (back, saved) = load(&dir).unwrap();
        assert_eq!(saved, SavedTopo { nodes: 2, devices_per_node: 2 });
        assert_eq!(back.step, state.step);
        assert_eq!(back.seed, state.seed);
        assert_eq!(back.rng_state, state.rng_state);
        assert_eq!(back.predictor_window, state.predictor_window);
        assert_eq!(back.mem_slots, state.mem_slots);
        assert_eq!(back.overlap_degree, state.overlap_degree);
        assert_eq!(back.layers.len(), 3);
        for (bl, sl) in back.layers.iter().zip(state.layers.iter()) {
            assert_eq!(bl.owners, sl.owners);
            assert_eq!(bl.predictor_history, sl.predictor_history);
            for (a, b) in bl.experts.iter().zip(sl.experts.iter()) {
                assert_eq!(a, b, "expert state must be bit-identical");
            }
            assert_allclose(&bl.gate_w, &sl.gate_w, 0.0, 0.0);
        }
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn shrinking_resave_removes_stale_rank_files() {
        let dir = tmpdir("shrink-resave");
        // First save on 4 devices, then re-save the (re-owned) state on 2.
        save(&dir, &test_state(8, 4, 21), &Topology::cluster_a(2, 2)).unwrap();
        assert!(dir.join("rank-3.bin").exists());
        save(&dir, &test_state(8, 2, 21), &Topology::cluster_a(1, 2)).unwrap();
        assert!(dir.join("rank-1.bin").exists());
        assert!(!dir.join("rank-2.bin").exists(), "stale rank file must be removed");
        assert!(!dir.join("rank-3.bin").exists(), "stale rank file must be removed");
        let (state, saved) = load(&dir).unwrap();
        assert_eq!(saved.world(), 2);
        assert_eq!(state.layers[0].experts.len(), 8);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn tampered_rank_blob_rejected() {
        let dir = tmpdir("tamper");
        let topo = Topology::cluster_a(1, 2);
        let state = test_state(4, 2, 7);
        save(&dir, &state, &topo).unwrap();
        let f = dir.join("rank-0.bin");
        let mut bytes = std::fs::read(&f).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 1;
        std::fs::write(&f, &bytes).unwrap();
        let err = load(&dir).unwrap_err().to_string();
        assert!(err.contains("checksum") || err.contains("corrupt"), "{err}");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn missing_expert_detected() {
        let dir = tmpdir("missing");
        let topo = Topology::cluster_a(1, 2);
        let state = test_state(4, 2, 9);
        save(&dir, &state, &topo).unwrap();
        // Rewrite rank 1's blob as empty (no experts) and fix the manifest
        // checksum so only the completeness check can catch it.
        let empty = shard::encode_rank(&state, 1, &[Vec::new()]);
        std::fs::write(dir.join("rank-1.bin"), &empty).unwrap();
        let manifest = std::fs::read_to_string(dir.join("manifest.json")).unwrap();
        let mut doc = Json::parse(&manifest).unwrap();
        if let Json::Obj(map) = &mut doc {
            if let Some(Json::Arr(ranks)) = map.get_mut("ranks") {
                if let Json::Obj(r1) = &mut ranks[1] {
                    r1.insert(
                        "fnv".into(),
                        Json::Str(format!("{:#018x}", format::fnv1a64(&empty))),
                    );
                    r1.insert("bytes".into(), empty.len().into());
                }
            }
        }
        std::fs::write(dir.join("manifest.json"), doc.to_string_pretty()).unwrap();
        let err = load(&dir).unwrap_err().to_string();
        assert!(err.contains("missing from every rank shard"), "{err}");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn v1_manifest_gets_migration_error() {
        let dir = tmpdir("v1-manifest");
        let topo = Topology::cluster_a(1, 2);
        let state = test_state(4, 2, 13);
        save(&dir, &state, &topo).unwrap();
        let manifest = std::fs::read_to_string(dir.join("manifest.json")).unwrap();
        let mut doc = Json::parse(&manifest).unwrap();
        if let Json::Obj(map) = &mut doc {
            map.insert("version".into(), 1usize.into());
        }
        std::fs::write(dir.join("manifest.json"), doc.to_string_pretty()).unwrap();
        let err = load(&dir).unwrap_err().to_string();
        assert!(err.contains("v1") && err.contains("single-layer"), "{err}");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn save_rejects_out_of_range_owner() {
        let dir = tmpdir("badowner");
        let topo = Topology::cluster_a(1, 2);
        let mut state = test_state(4, 2, 11);
        state.layers[0].owners[2] = 9;
        assert!(save(&dir, &state, &topo).is_err());
        let _ = std::fs::remove_dir_all(&dir);
    }
}
