//! Blob schemas: the per-rank shard blob and the replicated global blob.
//!
//! Schema version 2 (field order is the contract; see `DESIGN.md`). The
//! engine is multi-layer, so both blobs carry a layer-count header and one
//! section per layer:
//!
//! ```text
//! global.bin:  step u64 | seed u64 | data_shards u64 | dims 5×u64 |
//!              num_layers u64 | reshard_every u64 | predictor_window u64 |
//!              rng 4×u64 | mem_slots u64 | overlap_degree u64 |
//!              per layer: gate_w f32s | history_rows u64 | rows×f64s
//! rank-r.bin:  rank u64 | num_layers u64 | per layer: num_experts u64 |
//!              per expert: id u64 | t u32 | chunk f32s | m f32s | v f32s
//! ```
//!
//! Both are wrapped in the [`super::format`] header/trailer; v1 blobs are
//! rejected by [`super::format::Reader::open`] with a migration error.

use crate::fssdp::LayerDims;

use super::format::{Reader, Writer};
use super::{ExpertState, LayerCkpt, TrainState};

/// Encode the replicated (non-sharded) metadata of a checkpoint.
pub fn encode_global(state: &TrainState) -> Vec<u8> {
    let mut w = Writer::new();
    w.put_u64(state.step);
    w.put_u64(state.seed);
    w.put_usize(state.data_shards);
    w.put_usize(state.dims.tokens);
    w.put_usize(state.dims.d_model);
    w.put_usize(state.dims.d_ffn);
    w.put_usize(state.dims.experts);
    w.put_usize(state.dims.cap);
    w.put_usize(state.layers.len());
    w.put_usize(state.reshard_every);
    w.put_usize(state.predictor_window);
    for &s in &state.rng_state {
        w.put_u64(s);
    }
    w.put_usize(state.mem_slots);
    w.put_usize(state.overlap_degree);
    for layer in &state.layers {
        w.put_f32s(&layer.gate_w);
        w.put_usize(layer.predictor_history.len());
        for row in &layer.predictor_history {
            w.put_f64s(row);
        }
    }
    w.finish()
}

/// Decode a [`encode_global`] blob. The returned state has empty
/// `experts`/`owners` in every layer — the caller fills them from the rank
/// shards.
pub fn decode_global(bytes: &[u8]) -> anyhow::Result<TrainState> {
    let mut r = Reader::open(bytes)?;
    let step = r.take_u64()?;
    let seed = r.take_u64()?;
    let data_shards = r.take_usize()?;
    let dims = LayerDims {
        tokens: r.take_usize()?,
        d_model: r.take_usize()?,
        d_ffn: r.take_usize()?,
        experts: r.take_usize()?,
        cap: r.take_usize()?,
    };
    let num_layers = r.take_usize()?;
    anyhow::ensure!(num_layers >= 1, "global blob: zero layers");
    anyhow::ensure!(num_layers <= 1 << 16, "global blob: implausible layer count {num_layers}");
    let reshard_every = r.take_usize()?;
    let predictor_window = r.take_usize()?;
    anyhow::ensure!(predictor_window >= 1, "global blob: predictor window 0");
    let mut rng_state = [0u64; 4];
    for s in &mut rng_state {
        *s = r.take_u64()?;
    }
    let mem_slots = r.take_usize()?;
    let overlap_degree = r.take_usize()?;
    let mut layers = Vec::with_capacity(num_layers);
    for l in 0..num_layers {
        let gate_w = r.take_f32s()?;
        anyhow::ensure!(
            gate_w.len() == dims.d_model * dims.experts,
            "global blob layer {l}: gate_w has {} floats, dims imply {}",
            gate_w.len(),
            dims.d_model * dims.experts
        );
        let rows = r.take_usize()?;
        let mut predictor_history = Vec::with_capacity(rows.min(1024));
        for _ in 0..rows {
            let row = r.take_f64s()?;
            anyhow::ensure!(
                row.len() == dims.experts,
                "global blob layer {l}: history row has {} entries, expected {}",
                row.len(),
                dims.experts
            );
            predictor_history.push(row);
        }
        layers.push(LayerCkpt {
            owners: Vec::new(),
            experts: Vec::new(),
            gate_w,
            predictor_history,
        });
    }
    r.done()?;
    Ok(TrainState {
        step,
        dims,
        seed,
        data_shards,
        layers,
        predictor_window,
        rng_state,
        mem_slots,
        overlap_degree,
        reshard_every,
    })
}

/// One decoded rank shard.
#[derive(Debug, Clone)]
pub struct RankShard {
    pub rank: usize,
    /// Per layer: `(expert_id, state)` pairs, in id order.
    pub layers: Vec<Vec<(usize, ExpertState)>>,
}

/// Encode rank `r`'s shard: for every layer, the durable state of the
/// experts in `expert_ids[layer]`.
pub fn encode_rank(state: &TrainState, r: usize, expert_ids: &[Vec<usize>]) -> Vec<u8> {
    assert_eq!(expert_ids.len(), state.layers.len(), "one id list per layer");
    let mut w = Writer::new();
    w.put_usize(r);
    w.put_usize(state.layers.len());
    for (layer, ids) in state.layers.iter().zip(expert_ids.iter()) {
        w.put_usize(ids.len());
        for &e in ids {
            let st = &layer.experts[e];
            w.put_usize(e);
            w.put_u32(st.t);
            w.put_f32s(&st.chunk);
            w.put_f32s(&st.m);
            w.put_f32s(&st.v);
        }
    }
    w.finish()
}

/// Decode a [`encode_rank`] blob, validating every buffer against the
/// manifest's `chunk_len` and `layers`.
pub fn decode_rank(bytes: &[u8], chunk_len: usize, num_layers: usize) -> anyhow::Result<RankShard> {
    let mut r = Reader::open(bytes)?;
    let rank = r.take_usize()?;
    let nl = r.take_usize()?;
    anyhow::ensure!(
        nl == num_layers,
        "rank {rank}: blob holds {nl} layers, manifest says {num_layers}"
    );
    let mut layers = Vec::with_capacity(nl);
    for l in 0..nl {
        let n = r.take_usize()?;
        let mut experts = Vec::with_capacity(n.min(1 << 20));
        for _ in 0..n {
            let e = r.take_usize()?;
            let t = r.take_u32()?;
            let chunk = r.take_f32s()?;
            let m = r.take_f32s()?;
            let v = r.take_f32s()?;
            for (name, buf) in [("chunk", &chunk), ("m", &m), ("v", &v)] {
                anyhow::ensure!(
                    buf.len() == chunk_len,
                    "rank {rank} layer {l} expert {e}: {name} has {} floats, expected {chunk_len}",
                    buf.len()
                );
            }
            experts.push((e, ExpertState { chunk, m, v, t }));
        }
        layers.push(experts);
    }
    r.done()?;
    Ok(RankShard { rank, layers })
}

#[cfg(test)]
mod tests {
    use super::super::test_state_layers;
    use super::*;

    #[test]
    fn global_roundtrip() {
        let state = test_state_layers(6, 3, 3, 5);
        let bytes = encode_global(&state);
        let back = decode_global(&bytes).unwrap();
        assert_eq!(back.step, state.step);
        assert_eq!(back.seed, state.seed);
        assert_eq!(back.dims.chunk_len(), state.dims.chunk_len());
        assert_eq!(back.layers.len(), 3);
        assert_eq!(back.reshard_every, state.reshard_every);
        for (a, b) in back.layers.iter().zip(state.layers.iter()) {
            assert_eq!(a.gate_w, b.gate_w);
            assert_eq!(a.predictor_history, b.predictor_history);
            assert!(a.experts.is_empty());
        }
        assert_eq!(back.rng_state, state.rng_state);
    }

    #[test]
    fn rank_roundtrip_and_validation() {
        let state = test_state_layers(6, 3, 2, 5);
        let ids = vec![vec![1usize, 4], vec![0usize]];
        let bytes = encode_rank(&state, 2, &ids);
        let shard = decode_rank(&bytes, state.dims.chunk_len(), 2).unwrap();
        assert_eq!(shard.rank, 2);
        assert_eq!(shard.layers.len(), 2);
        assert_eq!(shard.layers[0].len(), 2);
        assert_eq!(shard.layers[0][0].0, 1);
        assert_eq!(shard.layers[0][0].1, state.layers[0].experts[1]);
        assert_eq!(shard.layers[0][1].1, state.layers[0].experts[4]);
        assert_eq!(shard.layers[1][0].1, state.layers[1].experts[0]);
        // wrong chunk_len rejected
        assert!(decode_rank(&bytes, state.dims.chunk_len() + 1, 2).is_err());
        // wrong layer count rejected
        assert!(decode_rank(&bytes, state.dims.chunk_len(), 3).is_err());
    }
}
