//! Blob schemas: the per-rank shard blob and the replicated global blob.
//!
//! Schema version 1 (field order is the contract; see `DESIGN.md`):
//!
//! ```text
//! global.bin:  step u64 | seed u64 | data_shards u64 | dims 5×u64 |
//!              gate_w f32s | predictor_window u64 | history_rows u64 |
//!              rows×f64s | rng 4×u64 | mem_slots u64 | overlap_degree u64
//! rank-r.bin:  rank u64 | num_experts u64 | per expert:
//!              id u64 | t u32 | chunk f32s | m f32s | v f32s
//! ```
//!
//! Both are wrapped in the [`super::format`] header/trailer.

use crate::fssdp::LayerDims;

use super::format::{Reader, Writer};
use super::{ExpertState, TrainState};

/// Encode the replicated (non-sharded) metadata of a checkpoint.
pub fn encode_global(state: &TrainState) -> Vec<u8> {
    let mut w = Writer::new();
    w.put_u64(state.step);
    w.put_u64(state.seed);
    w.put_usize(state.data_shards);
    w.put_usize(state.dims.tokens);
    w.put_usize(state.dims.d_model);
    w.put_usize(state.dims.d_ffn);
    w.put_usize(state.dims.experts);
    w.put_usize(state.dims.cap);
    w.put_f32s(&state.gate_w);
    w.put_usize(state.predictor_window);
    w.put_usize(state.predictor_history.len());
    for row in &state.predictor_history {
        w.put_f64s(row);
    }
    for &s in &state.rng_state {
        w.put_u64(s);
    }
    w.put_usize(state.mem_slots);
    w.put_usize(state.overlap_degree);
    w.finish()
}

/// Decode a [`encode_global`] blob. The returned state has empty
/// `experts`/`owners` — the caller fills them from the rank shards.
pub fn decode_global(bytes: &[u8]) -> anyhow::Result<TrainState> {
    let mut r = Reader::open(bytes)?;
    let step = r.take_u64()?;
    let seed = r.take_u64()?;
    let data_shards = r.take_usize()?;
    let dims = LayerDims {
        tokens: r.take_usize()?,
        d_model: r.take_usize()?,
        d_ffn: r.take_usize()?,
        experts: r.take_usize()?,
        cap: r.take_usize()?,
    };
    let gate_w = r.take_f32s()?;
    anyhow::ensure!(
        gate_w.len() == dims.d_model * dims.experts,
        "global blob: gate_w has {} floats, dims imply {}",
        gate_w.len(),
        dims.d_model * dims.experts
    );
    let predictor_window = r.take_usize()?;
    anyhow::ensure!(predictor_window >= 1, "global blob: predictor window 0");
    let rows = r.take_usize()?;
    let mut predictor_history = Vec::with_capacity(rows.min(1024));
    for _ in 0..rows {
        let row = r.take_f64s()?;
        anyhow::ensure!(
            row.len() == dims.experts,
            "global blob: history row has {} entries, expected {}",
            row.len(),
            dims.experts
        );
        predictor_history.push(row);
    }
    let mut rng_state = [0u64; 4];
    for s in &mut rng_state {
        *s = r.take_u64()?;
    }
    let mem_slots = r.take_usize()?;
    let overlap_degree = r.take_usize()?;
    r.done()?;
    Ok(TrainState {
        step,
        dims,
        seed,
        data_shards,
        experts: Vec::new(),
        owners: Vec::new(),
        gate_w,
        predictor_window,
        predictor_history,
        rng_state,
        mem_slots,
        overlap_degree,
    })
}

/// One decoded rank shard.
#[derive(Debug, Clone)]
pub struct RankShard {
    pub rank: usize,
    /// `(expert_id, state)` pairs, in id order.
    pub experts: Vec<(usize, ExpertState)>,
}

/// Encode rank `r`'s shard: the durable state of `expert_ids`.
pub fn encode_rank(state: &TrainState, r: usize, expert_ids: &[usize]) -> Vec<u8> {
    let mut w = Writer::new();
    w.put_usize(r);
    w.put_usize(expert_ids.len());
    for &e in expert_ids {
        let st = &state.experts[e];
        w.put_usize(e);
        w.put_u32(st.t);
        w.put_f32s(&st.chunk);
        w.put_f32s(&st.m);
        w.put_f32s(&st.v);
    }
    w.finish()
}

/// Decode a [`encode_rank`] blob, validating every buffer against the
/// manifest's `chunk_len`.
pub fn decode_rank(bytes: &[u8], chunk_len: usize) -> anyhow::Result<RankShard> {
    let mut r = Reader::open(bytes)?;
    let rank = r.take_usize()?;
    let n = r.take_usize()?;
    let mut experts = Vec::with_capacity(n.min(1 << 20));
    for _ in 0..n {
        let e = r.take_usize()?;
        let t = r.take_u32()?;
        let chunk = r.take_f32s()?;
        let m = r.take_f32s()?;
        let v = r.take_f32s()?;
        for (name, buf) in [("chunk", &chunk), ("m", &m), ("v", &v)] {
            anyhow::ensure!(
                buf.len() == chunk_len,
                "rank {rank} expert {e}: {name} has {} floats, expected {chunk_len}",
                buf.len()
            );
        }
        experts.push((e, ExpertState { chunk, m, v, t }));
    }
    r.done()?;
    Ok(RankShard { rank, experts })
}

#[cfg(test)]
mod tests {
    use super::super::test_state;
    use super::*;

    #[test]
    fn global_roundtrip() {
        let state = test_state(6, 3, 5);
        let bytes = encode_global(&state);
        let back = decode_global(&bytes).unwrap();
        assert_eq!(back.step, state.step);
        assert_eq!(back.seed, state.seed);
        assert_eq!(back.dims.chunk_len(), state.dims.chunk_len());
        assert_eq!(back.gate_w, state.gate_w);
        assert_eq!(back.predictor_history, state.predictor_history);
        assert_eq!(back.rng_state, state.rng_state);
        assert!(back.experts.is_empty());
    }

    #[test]
    fn rank_roundtrip_and_validation() {
        let state = test_state(6, 3, 5);
        let ids = vec![1usize, 4];
        let bytes = encode_rank(&state, 2, &ids);
        let shard = decode_rank(&bytes, state.dims.chunk_len()).unwrap();
        assert_eq!(shard.rank, 2);
        assert_eq!(shard.experts.len(), 2);
        assert_eq!(shard.experts[0].0, 1);
        assert_eq!(shard.experts[0].1, state.experts[1]);
        assert_eq!(shard.experts[1].1, state.experts[4]);
        // wrong chunk_len rejected
        assert!(decode_rank(&bytes, state.dims.chunk_len() + 1).is_err());
    }
}
