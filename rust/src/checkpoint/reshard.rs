//! Elastic resume planning: lay a restored multi-layer shard set out on a
//! (possibly different) topology.
//!
//! * Same world size → keep the saved owner maps verbatim. Zero movement,
//!   and the resumed run is **bit-identical** to the uninterrupted one
//!   (same placements ⇒ same reduction orders).
//! * Different world size → re-run the heterogeneous sharding planner
//!   (Algorithm 2, [`crate::sharding`]) **jointly over all layers** — the
//!   unified-memory balance of §4.3 — using the restored load-predictor
//!   windows, exactly what a fresh re-shard would do. FlexMoE/LAER-MoE make
//!   the same observation from the placement side: expert state can be
//!   re-laid-out across a changed device set because the durable state is
//!   placement-free.

use crate::loadsim::LoadPredictor;
use crate::placement::Placement;
use crate::sharding;
use crate::topology::{DeviceId, Topology};

use super::TrainState;

/// How a restored checkpoint maps onto the resume topology.
#[derive(Debug, Clone)]
pub struct ReshardPlan {
    /// New owner partition per layer: exactly one holder per expert.
    pub shards: Vec<Placement>,
    /// `(layer, expert)` pairs whose owner rank changed vs the checkpoint.
    pub moved_experts: Vec<(usize, usize)>,
    /// Bytes those moves carry (params + Adam m/v + step counter).
    pub bytes_moved: usize,
    /// True when the saved layouts were reused verbatim.
    pub kept_saved_layout: bool,
}

/// Bytes one expert's durable state occupies in host memory (f32 chunk +
/// f32 m + f32 v + u32 t).
pub fn expert_state_bytes(chunk_len: usize) -> usize {
    chunk_len * 4 * 3 + 4
}

/// Plan the owner layouts for resuming `state` on `topo`.
pub fn plan(state: &TrainState, old_world: usize, topo: &Topology) -> anyhow::Result<ReshardPlan> {
    let experts = state.dims.experts;
    let world = topo.num_devices();
    anyhow::ensure!(world > 0, "resume topology has no devices");
    anyhow::ensure!(!state.layers.is_empty(), "checkpoint holds no layers");
    anyhow::ensure!(experts > 0, "checkpoint holds no experts");
    for (l, layer) in state.layers.iter().enumerate() {
        anyhow::ensure!(layer.experts.len() == experts, "layer {l} expert count mismatch");
        anyhow::ensure!(
            layer.owners.len() == experts,
            "layer {l} owner map covers {} experts, state has {experts}",
            layer.owners.len()
        );
    }

    let (shards, kept) = if world == old_world {
        (
            state
                .layers
                .iter()
                .map(|layer| {
                    Placement::from_pairs(
                        experts,
                        world,
                        layer.owners.iter().enumerate().map(|(e, &r)| (e, DeviceId(r))),
                    )
                })
                .collect::<Vec<Placement>>(),
            true,
        )
    } else {
        // Re-run Algorithm 2 jointly over all layers with the same load
        // statistics the engine's next materialization will see: restore
        // each layer's predictor exactly as `resume_with` will and use its
        // prediction (uniform on an empty window — the cold-start rule).
        let loads: Vec<Vec<f64>> = state
            .layers
            .iter()
            .map(|layer| {
                LoadPredictor::restore(
                    experts,
                    state.predictor_window,
                    layer.predictor_history.clone(),
                )
                .predict()
            })
            .collect();
        let t = state.overlap_degree.min(experts);
        let plan = sharding::heterogeneous(topo, &loads, t);
        (plan.layers, false)
    };

    let mut moved_experts = Vec::new();
    for (l, (layer, new)) in state.layers.iter().zip(shards.iter()).enumerate() {
        anyhow::ensure!(new.is_partition(), "reshard produced a non-partition layout (layer {l})");
        for e in 0..experts {
            let new_owner = new.holders(e).next().expect("partition has a holder");
            if layer.owners[e] != new_owner.0 {
                moved_experts.push((l, e));
            }
        }
    }
    let bytes_moved = moved_experts.len() * expert_state_bytes(state.dims.chunk_len());
    Ok(ReshardPlan { shards, moved_experts, bytes_moved, kept_saved_layout: kept })
}

#[cfg(test)]
mod tests {
    use super::super::{test_state, test_state_layers};
    use super::*;

    #[test]
    fn same_world_keeps_saved_layout() {
        let state = test_state_layers(8, 4, 3, 3);
        let topo = Topology::cluster_a(2, 2);
        let p = plan(&state, 4, &topo).unwrap();
        assert!(p.kept_saved_layout);
        assert!(p.moved_experts.is_empty());
        assert_eq!(p.bytes_moved, 0);
        assert_eq!(p.shards.len(), 3);
        for (l, layer) in state.layers.iter().enumerate() {
            for (e, &o) in layer.owners.iter().enumerate() {
                assert!(p.shards[l].contains(e, DeviceId(o)));
                assert_eq!(p.shards[l].replication(e), 1);
            }
        }
    }

    #[test]
    fn shrink_and_grow_produce_valid_partitions() {
        let state = test_state_layers(16, 4, 2, 11);
        for (nodes, dpn) in [(1, 2), (2, 4), (2, 1)] {
            let topo = Topology::cluster_a(nodes, dpn);
            let p = plan(&state, 4, &topo).unwrap();
            assert!(!p.kept_saved_layout);
            for shards in &p.shards {
                assert!(shards.is_partition());
                assert_eq!(shards.num_devices(), topo.num_devices());
            }
            // joint (all-layer) slot balance within one expert
            let loads: Vec<usize> = topo
                .all_devices()
                .map(|d| p.shards.iter().map(|s| s.load_of(d)).sum())
                .collect();
            let (mx, mn) = (loads.iter().max().unwrap(), loads.iter().min().unwrap());
            assert!(mx - mn <= 1, "unbalanced slots {loads:?}");
            assert_eq!(
                p.bytes_moved,
                p.moved_experts.len() * expert_state_bytes(state.dims.chunk_len())
            );
        }
    }

    #[test]
    fn shrink_moves_dead_ranks_experts() {
        let state = test_state(8, 4, 5);
        let topo = Topology::cluster_a(1, 2); // world 4 -> 2
        let p = plan(&state, 4, &topo).unwrap();
        // every expert owned by rank 2 or 3 must have moved
        for (e, &o) in state.layers[0].owners.iter().enumerate() {
            if o >= 2 {
                assert!(
                    p.moved_experts.contains(&(0, e)),
                    "expert {e} owned by dead rank {o}"
                );
            }
        }
    }
}
