//! Elastic resume planning: lay a restored shard set out on a (possibly
//! different) topology.
//!
//! * Same world size → keep the saved owner map verbatim. Zero movement,
//!   and the resumed run is **bit-identical** to the uninterrupted one
//!   (same placement ⇒ same reduction orders).
//! * Different world size → re-run the heterogeneous sharding planner
//!   (Algorithm 2, [`crate::sharding`]) over the restored load-predictor
//!   window, exactly what a fresh re-shard would do. FlexMoE/LAER-MoE make
//!   the same observation from the placement side: expert state can be
//!   re-laid-out across a changed device set because the durable state is
//!   placement-free.

use crate::placement::Placement;
use crate::sharding;
use crate::topology::{DeviceId, Topology};

use super::TrainState;

/// How a restored checkpoint maps onto the resume topology.
#[derive(Debug, Clone)]
pub struct ReshardPlan {
    /// New owner partition: exactly one holder per expert.
    pub shards: Placement,
    /// Experts whose owner rank changed relative to the checkpoint.
    pub moved_experts: Vec<usize>,
    /// Bytes those moves carry (params + Adam m/v + step counter).
    pub bytes_moved: usize,
    /// True when the saved layout was reused verbatim.
    pub kept_saved_layout: bool,
}

/// Bytes one expert's durable state occupies in host memory (f32 chunk +
/// f32 m + f32 v + u32 t).
pub fn expert_state_bytes(chunk_len: usize) -> usize {
    chunk_len * 4 * 3 + 4
}

/// Plan the owner layout for resuming `state` on `topo`.
pub fn plan(state: &TrainState, old_world: usize, topo: &Topology) -> anyhow::Result<ReshardPlan> {
    let experts = state.experts.len();
    let world = topo.num_devices();
    anyhow::ensure!(world > 0, "resume topology has no devices");
    anyhow::ensure!(experts > 0, "checkpoint holds no experts");
    anyhow::ensure!(
        state.owners.len() == experts,
        "owner map covers {} experts, state has {experts}",
        state.owners.len()
    );

    let (shards, kept) = if world == old_world {
        (
            Placement::from_pairs(
                experts,
                world,
                state.owners.iter().enumerate().map(|(e, &r)| (e, DeviceId(r))),
            ),
            true,
        )
    } else {
        // Re-run Algorithm 2 with the same load statistics the engine's
        // next materialization will see (the restored sliding window).
        let loads = if state.predictor_history.is_empty() {
            vec![1.0 / experts as f64; experts]
        } else {
            let mut avg = vec![0.0f64; experts];
            for row in &state.predictor_history {
                for (a, v) in avg.iter_mut().zip(row.iter()) {
                    *a += v;
                }
            }
            let n = state.predictor_history.len() as f64;
            for a in &mut avg {
                *a /= n;
            }
            avg
        };
        let t = state.overlap_degree.min(experts);
        let plan = sharding::heterogeneous(topo, &[loads], t);
        (plan.layers.into_iter().next().expect("single-layer plan"), false)
    };

    anyhow::ensure!(shards.is_partition(), "reshard produced a non-partition layout");
    let moved_experts: Vec<usize> = (0..experts)
        .filter(|&e| {
            let new_owner = shards.holders(e).next().expect("partition has a holder");
            state.owners[e] != new_owner.0
        })
        .collect();
    let bytes_moved = moved_experts.len() * expert_state_bytes(state.dims.chunk_len());
    Ok(ReshardPlan { shards, moved_experts, bytes_moved, kept_saved_layout: kept })
}

#[cfg(test)]
mod tests {
    use super::super::test_state;
    use super::*;

    #[test]
    fn same_world_keeps_saved_layout() {
        let state = test_state(8, 4, 3);
        let topo = Topology::cluster_a(2, 2);
        let p = plan(&state, 4, &topo).unwrap();
        assert!(p.kept_saved_layout);
        assert!(p.moved_experts.is_empty());
        assert_eq!(p.bytes_moved, 0);
        for (e, &o) in state.owners.iter().enumerate() {
            assert!(p.shards.contains(e, DeviceId(o)));
            assert_eq!(p.shards.replication(e), 1);
        }
    }

    #[test]
    fn shrink_and_grow_produce_valid_partitions() {
        let state = test_state(16, 4, 11);
        for (nodes, dpn) in [(1, 2), (2, 4), (2, 1)] {
            let topo = Topology::cluster_a(nodes, dpn);
            let p = plan(&state, 4, &topo).unwrap();
            assert!(!p.kept_saved_layout);
            assert!(p.shards.is_partition());
            assert_eq!(p.shards.num_devices(), topo.num_devices());
            // slot balance within one expert
            let loads: Vec<usize> =
                topo.all_devices().map(|d| p.shards.load_of(d)).collect();
            let (mx, mn) = (loads.iter().max().unwrap(), loads.iter().min().unwrap());
            assert!(mx - mn <= 1, "unbalanced slots {loads:?}");
            assert_eq!(p.bytes_moved, p.moved_experts.len() * expert_state_bytes(state.dims.chunk_len()));
        }
    }

    #[test]
    fn shrink_moves_dead_ranks_experts() {
        let state = test_state(8, 4, 5);
        let topo = Topology::cluster_a(1, 2); // world 4 -> 2
        let p = plan(&state, 4, &topo).unwrap();
        // every expert owned by rank 2 or 3 must have moved
        for (e, &o) in state.owners.iter().enumerate() {
            if o >= 2 {
                assert!(p.moved_experts.contains(&e), "expert {e} owned by dead rank {o}");
            }
        }
    }
}
