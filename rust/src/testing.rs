//! Property-testing harness (the registry snapshot has no `proptest`).
//!
//! [`prop_check`] runs a property over many generated cases from a seeded
//! [`Rng`](crate::util::rng::Rng); on failure it reports the failing case's
//! seed so the case can be replayed deterministically, and performs a simple
//! numeric shrink by retrying the generator with "smaller" size hints.

use crate::util::rng::Rng;

/// Configuration for a property run.
#[derive(Debug, Clone)]
pub struct PropConfig {
    /// Number of generated cases.
    pub cases: usize,
    /// Base seed (each case uses `seed + case_index`).
    pub seed: u64,
}

impl Default for PropConfig {
    fn default() -> Self {
        // HECATE_PROP_CASES overrides for a heavier local run.
        let cases = std::env::var("HECATE_PROP_CASES")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(128);
        PropConfig { cases, seed: 0xC0FFEE }
    }
}

/// Run `prop` over `cases` generated inputs. `gen` receives a seeded RNG and
/// a *size* hint growing from small to large across cases (so early cases are
/// small and easier to debug). `prop` returns `Err(reason)` to signal
/// failure.
pub fn prop_check<T, G, P>(cfg: &PropConfig, mut gen: G, mut prop: P)
where
    T: std::fmt::Debug,
    G: FnMut(&mut Rng, usize) -> T,
    P: FnMut(&T) -> Result<(), String>,
{
    for case in 0..cfg.cases {
        let seed = cfg.seed.wrapping_add(case as u64);
        let mut rng = Rng::new(seed);
        // size ramps 1..=32 over the run
        let size = 1 + (case * 32) / cfg.cases.max(1);
        let input = gen(&mut rng, size);
        if let Err(reason) = prop(&input) {
            // try to find a smaller failing case by regenerating at smaller sizes
            for shrink_size in (1..size).rev() {
                let mut srng = Rng::new(seed);
                let smaller = gen(&mut srng, shrink_size);
                if prop(&smaller).is_err() {
                    panic!(
                        "property failed (seed={seed}, size={shrink_size}, shrunk from {size}):\n  input: {smaller:#?}\n  reason: {reason}"
                    );
                }
            }
            panic!(
                "property failed (seed={seed}, size={size}):\n  input: {input:#?}\n  reason: {reason}"
            );
        }
    }
}

/// Shorthand: run with the default config.
pub fn check<T, G, P>(gen: G, prop: P)
where
    T: std::fmt::Debug,
    G: FnMut(&mut Rng, usize) -> T,
    P: FnMut(&T) -> Result<(), String>,
{
    prop_check(&PropConfig::default(), gen, prop)
}

/// Assert two f32 slices are element-wise close.
pub fn assert_allclose(a: &[f32], b: &[f32], atol: f32, rtol: f32) {
    assert_eq!(a.len(), b.len(), "length mismatch {} vs {}", a.len(), b.len());
    for (i, (&x, &y)) in a.iter().zip(b.iter()).enumerate() {
        let tol = atol + rtol * y.abs();
        assert!(
            (x - y).abs() <= tol,
            "allclose failed at [{i}]: {x} vs {y} (tol {tol})"
        );
    }
}

/// Flatten every layer's expert parameter chunks of an engine, layer-major
/// — the shared shape for bit-identity comparisons across executors,
/// checkpoints, and elastic resumes.
pub fn all_chunks(e: &crate::fssdp::FssdpEngine) -> Vec<Vec<f32>> {
    let mut out = Vec::new();
    for l in 0..e.num_layers() {
        for x in 0..e.dims.experts {
            out.push(e.expert_chunk_at(l, x).to_vec());
        }
    }
    out
}

/// Relative max-abs error between two slices (0 when equal).
pub fn max_rel_err(a: &[f32], b: &[f32]) -> f32 {
    assert_eq!(a.len(), b.len());
    a.iter()
        .zip(b.iter())
        .map(|(&x, &y)| (x - y).abs() / y.abs().max(1e-6))
        .fold(0.0, f32::max)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        prop_check(
            &PropConfig { cases: 50, seed: 1 },
            |rng, size| rng.below(size.max(1) * 10),
            |_| {
                count += 1;
                Ok(())
            },
        );
        assert_eq!(count, 50);
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics_with_seed() {
        prop_check(
            &PropConfig { cases: 50, seed: 1 },
            |rng, _| rng.below(100),
            |&x| if x < 1000 { Err(format!("x={x}")) } else { Ok(()) },
        );
    }

    #[test]
    fn allclose_tolerances() {
        assert_allclose(&[1.0, 2.0], &[1.0 + 1e-6, 2.0 - 1e-6], 1e-5, 0.0);
    }

    #[test]
    #[should_panic(expected = "allclose failed")]
    fn allclose_catches_mismatch() {
        assert_allclose(&[1.0], &[1.1], 1e-3, 1e-3);
    }

    #[test]
    fn rel_err() {
        assert_eq!(max_rel_err(&[2.0], &[2.0]), 0.0);
        assert!((max_rel_err(&[2.2], &[2.0]) - 0.1).abs() < 1e-6);
    }
}
