//! Rank-level tracing & telemetry — zero overhead when disabled.
//!
//! Four pieces:
//!
//! 1. **[`TraceRecorder`]** — a per-rank span recorder. Each rank thread
//!    owns one (a plain `Vec<Event>`, no locks on the hot path); all
//!    recorders of a run share one monotonic epoch so timestamps line up
//!    across ranks. Instrumentation sites in the engine, the SPMD rank
//!    loop, and the communicator call [`TraceRecorder::span_from`] next
//!    to the existing phase timers — when telemetry is off the recorder
//!    is simply absent (`Option::None`) and the sites cost one branch.
//! 2. **Exporters** — [`chrome_trace`] renders a `chrome://tracing` /
//!    Perfetto document (one timeline row per rank plus a `comm` row for
//!    wire-level events) and [`append_jsonl`] streams events as JSON
//!    lines through the [`crate::util::json`] canonicalizer.
//! 3. **[`analyze`]** — the offline pass: per-step critical path, §4.3
//!    overlap efficiency, and the per-rank straggler report.
//! 4. **[`TraceWriter`]** — a [`StepObserver`] that drains the engine's
//!    accumulated events at every span boundary into a `--trace-out`
//!    directory ([`EVENTS_FILE`] appended incrementally,
//!    [`CHROME_TRACE_FILE`] rewritten).
//!
//! Determinism contract: tracing is observational. Recorders never touch
//! engine state, payloads, or message ordering, so a traced run is
//! bit-identical to an untraced one (locked by `tests/telemetry_trace.rs`).
//!
//! Transport note: comm events ([`Phase::SendChunk`]/[`Phase::RecvChunk`]
//! and the row/pacing phases) are recorded by the rank endpoint
//! (`RankComm`) *above* the pluggable transport, so timelines have the
//! same shape over the in-process fabric and the socket backend. Only the
//! delivery durations differ: modeled α–β in-flight time when paced, zero
//! over sockets — where real wire time surfaces as `SpagWait`/`SprsWait`
//! wall clock instead.

pub mod analyze;
pub mod metrics_io;

use std::collections::BTreeSet;
use std::io::Write as _;
use std::path::{Path, PathBuf};
use std::time::{Duration, Instant};

use crate::fssdp::{SpanCtx, StepObserver};
use crate::util::json::{obj, Json};

/// Broad classification of a [`Phase`], used for the Chrome-trace `cat`
/// field and the analyzer's busy/wait/wire accounting.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Kind {
    /// On-thread arithmetic: the rank is doing useful work.
    Compute,
    /// On-thread blocked time: the rank is waiting on a collective.
    CommWait,
    /// Wire-level bookkeeping (sends, deliveries, pacing sleeps); rendered
    /// on the per-rank `comm` row, excluded from busy-time accounting.
    Comm,
}

impl Kind {
    pub fn as_str(self) -> &'static str {
        match self {
            Kind::Compute => "compute",
            Kind::CommWait => "comm_wait",
            Kind::Comm => "comm",
        }
    }
}

/// What one span measured. Engine/rank phases mirror the existing
/// `StepPhases` / `spmd.*` timer taxonomy; comm phases come from the
/// communicator.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Phase {
    /// Materialization / collective planning (Algorithm 1).
    Plan,
    /// Sequential executor's in-line spAG (staged copy transfers).
    Materialize,
    /// SPMD: resident-chunk sends issued for an iteration's spAG.
    SpagIssue,
    /// SPMD: blocked waiting for spAG replica chunks to arrive.
    SpagWait,
    /// Gate forward (+ gate-decision allgather on the SPMD path).
    Gate,
    /// Expert FFN forward (`detail` = token rows computed).
    ExpertFwd,
    /// Expert FFN backward (`detail` = token rows computed).
    ExpertBwd,
    /// Combine / cotangent row exchange (allgather + ordered scatter).
    Combine,
    /// SPMD: stage-0 spRS reduction sends issued.
    SprsIssue,
    /// Blocked finishing spRS (reduce in plan order + scatter), or the
    /// sequential executor's in-line spRS.
    SprsWait,
    /// Adam owner updates + replica release (+ eager next-iter spAG issue).
    Adam,
    /// Algorithm 2 re-shard at a span boundary (`detail` = experts moved).
    Reshard,
    /// Comm: expert-chunk payload sent (spAG/spRS; `detail` = bytes).
    SendChunk,
    /// Comm: expert-chunk payload delivered (`dur` = modeled in-flight
    /// wire time under α–β pacing, 0 unpaced; `detail` = bytes).
    RecvChunk,
    /// Comm: row/control payload sent (gate/combine/cotangent).
    SendRow,
    /// Comm: row/control payload delivered (`dur` = modeled wire time).
    RecvRow,
    /// Comm: physical sleep enforcing the α–β link pacing model.
    PacingWait,
}

impl Phase {
    /// Every phase, in declaration order (stable for exports and tests).
    pub const ALL: [Phase; 17] = [
        Phase::Plan,
        Phase::Materialize,
        Phase::SpagIssue,
        Phase::SpagWait,
        Phase::Gate,
        Phase::ExpertFwd,
        Phase::ExpertBwd,
        Phase::Combine,
        Phase::SprsIssue,
        Phase::SprsWait,
        Phase::Adam,
        Phase::Reshard,
        Phase::SendChunk,
        Phase::RecvChunk,
        Phase::SendRow,
        Phase::RecvRow,
        Phase::PacingWait,
    ];

    pub fn as_str(self) -> &'static str {
        match self {
            Phase::Plan => "plan",
            Phase::Materialize => "materialize",
            Phase::SpagIssue => "spag_issue",
            Phase::SpagWait => "spag_wait",
            Phase::Gate => "gate",
            Phase::ExpertFwd => "expert_fwd",
            Phase::ExpertBwd => "expert_bwd",
            Phase::Combine => "combine",
            Phase::SprsIssue => "sprs_issue",
            Phase::SprsWait => "sprs_wait",
            Phase::Adam => "adam",
            Phase::Reshard => "reshard",
            Phase::SendChunk => "send_chunk",
            Phase::RecvChunk => "recv_chunk",
            Phase::SendRow => "send_row",
            Phase::RecvRow => "recv_row",
            Phase::PacingWait => "pacing_wait",
        }
    }

    /// Inverse of [`Phase::as_str`].
    pub fn parse(s: &str) -> Option<Phase> {
        Phase::ALL.into_iter().find(|p| p.as_str() == s)
    }

    pub fn kind(self) -> Kind {
        match self {
            Phase::Plan
            | Phase::Gate
            | Phase::ExpertFwd
            | Phase::ExpertBwd
            | Phase::Adam
            | Phase::Reshard => Kind::Compute,
            Phase::Materialize | Phase::SpagWait | Phase::Combine | Phase::SprsWait => {
                Kind::CommWait
            }
            Phase::SpagIssue
            | Phase::SprsIssue
            | Phase::SendChunk
            | Phase::RecvChunk
            | Phase::SendRow
            | Phase::RecvRow
            | Phase::PacingWait => Kind::Comm,
        }
    }
}

/// One recorded span: `(iter, layer, rank, phase)` plus a start timestamp
/// and duration in microseconds from the run's shared monotonic epoch.
/// `detail` is phase-specific (bytes, token rows, chunk/expert counts).
#[derive(Debug, Clone, PartialEq)]
pub struct Event {
    pub phase: Phase,
    pub iter: u32,
    pub layer: u32,
    pub rank: u32,
    pub ts_us: f64,
    pub dur_us: f64,
    pub detail: u64,
}

impl Event {
    /// Canonical JSON object (one [`EVENTS_FILE`] line).
    pub fn to_json(&self) -> Json {
        obj([
            ("phase", Json::Str(self.phase.as_str().into())),
            ("iter", Json::num(self.iter as f64)),
            ("layer", Json::num(self.layer as f64)),
            ("rank", Json::num(self.rank as f64)),
            ("ts_us", Json::num(self.ts_us)),
            ("dur_us", Json::num(self.dur_us)),
            ("detail", Json::num(self.detail as f64)),
        ])
    }

    /// Inverse of [`Event::to_json`].
    pub fn from_json(j: &Json) -> anyhow::Result<Event> {
        let phase_str = j
            .req("phase")?
            .as_str()
            .ok_or_else(|| anyhow::anyhow!("event `phase` must be a string"))?;
        let phase = Phase::parse(phase_str)
            .ok_or_else(|| anyhow::anyhow!("unknown trace phase `{phase_str}`"))?;
        let num = |key: &str| -> anyhow::Result<f64> {
            j.req(key)?
                .as_f64()
                .ok_or_else(|| anyhow::anyhow!("event `{key}` must be a number"))
        };
        Ok(Event {
            phase,
            iter: num("iter")? as u32,
            layer: num("layer")? as u32,
            rank: num("rank")? as u32,
            ts_us: num("ts_us")?,
            dur_us: num("dur_us")?,
            detail: num("detail")? as u64,
        })
    }
}

/// Telemetry knobs on the [`SessionConfig`](crate::fssdp::SessionConfig)
/// builder. Default (`enabled = false`) is the zero-overhead mode: no
/// recorder is created anywhere and every instrumentation site reduces to
/// an `Option` check.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct TelemetryConfig {
    /// Record spans during the run (in memory, drained via
    /// `Session::trace_events` / [`TraceWriter`]).
    pub enabled: bool,
    /// Directory for the exported trace (`--trace-out DIR`); implies
    /// `enabled`.
    pub trace_dir: Option<String>,
    /// Record the step meter — the per-rank memory ledger + load
    /// observatory (in memory, drained via `Session::meter_samples` /
    /// `MetricsWriter`).
    pub metrics: bool,
    /// Directory for metrics export (`--metrics-out DIR`); implies
    /// `metrics`.
    pub metrics_dir: Option<String>,
}

impl TelemetryConfig {
    /// Tracing on, no file export (programmatic consumers).
    pub fn enabled() -> TelemetryConfig {
        TelemetryConfig { enabled: true, ..TelemetryConfig::default() }
    }

    /// Tracing on, exporting into `dir`.
    pub fn to_dir(dir: impl Into<String>) -> TelemetryConfig {
        TelemetryConfig {
            enabled: true,
            trace_dir: Some(dir.into()),
            ..TelemetryConfig::default()
        }
    }
}

/// Per-rank span recorder. Owned by exactly one thread; all recorders of
/// a run share the epoch so their timestamps are directly comparable.
#[derive(Debug)]
pub struct TraceRecorder {
    epoch: Instant,
    rank: u32,
    events: Vec<Event>,
}

impl TraceRecorder {
    /// Fresh recorder with its own epoch (the run's time zero).
    pub fn new(rank: usize) -> TraceRecorder {
        TraceRecorder::with_epoch(Instant::now(), rank)
    }

    /// Recorder sharing an existing epoch (per-rank recorders of one run).
    pub fn with_epoch(epoch: Instant, rank: usize) -> TraceRecorder {
        TraceRecorder { epoch, rank: rank as u32, events: Vec::new() }
    }

    pub fn epoch(&self) -> Instant {
        self.epoch
    }

    pub fn rank(&self) -> usize {
        self.rank as usize
    }

    /// Record a span that started at `start` and ends now. Pairs with the
    /// existing `let t0 = Instant::now(); …; timer += t0.elapsed()` sites:
    /// the same `t0` is the span start, so tracing adds no extra clock
    /// read at span entry.
    pub fn span_from(
        &mut self,
        phase: Phase,
        iter: usize,
        layer: usize,
        start: Instant,
        detail: u64,
    ) {
        let dur = start.elapsed();
        self.event_at(phase, iter, layer, start, dur, detail);
    }

    /// Record a span with an explicit duration (comm events whose length
    /// is the modeled wire time rather than elapsed thread time).
    pub fn event_at(
        &mut self,
        phase: Phase,
        iter: usize,
        layer: usize,
        start: Instant,
        dur: Duration,
        detail: u64,
    ) {
        self.events.push(Event {
            phase,
            iter: iter as u32,
            layer: layer as u32,
            rank: self.rank,
            ts_us: start.saturating_duration_since(self.epoch).as_secs_f64() * 1e6,
            dur_us: dur.as_secs_f64() * 1e6,
            detail,
        });
    }

    pub fn events(&self) -> &[Event] {
        &self.events
    }

    pub fn len(&self) -> usize {
        self.events.len()
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// Merge another rank's events (same epoch) into this recorder.
    /// Per-rank event order is preserved — each rank's slice stays
    /// monotone even though the merged vector interleaves ranks.
    pub fn absorb(&mut self, mut other: TraceRecorder) {
        self.events.append(&mut other.events);
    }
}

/// Chrome-trace file name inside a `--trace-out` directory.
pub const CHROME_TRACE_FILE: &str = "trace.json";
/// JSONL event-stream file name inside a `--trace-out` directory.
pub const EVENTS_FILE: &str = "events.jsonl";
/// Comm events render on `tid = rank + COMM_TID_OFFSET` so each rank gets
/// a phase row and a separate wire row.
pub const COMM_TID_OFFSET: u32 = 1000;

/// Render events as a `chrome://tracing` / Perfetto document: complete
/// (`ph: "X"`) events, one timeline row per rank (`tid = rank`) plus a
/// `rank N comm` row for wire-level events, with `(iter, layer, detail)`
/// in `args`.
pub fn chrome_trace(events: &[Event]) -> Json {
    chrome_trace_with_counters(events, &[])
}

/// [`chrome_trace`] plus pre-rendered counter rows (`ph: "C"`, see
/// [`counter_rows`]) so Perfetto shows memory/load tracks next to the
/// span timeline.
pub fn chrome_trace_with_counters(events: &[Event], counters: &[Json]) -> Json {
    let ranks: BTreeSet<u32> = events.iter().map(|e| e.rank).collect();
    let mut out: Vec<Json> = Vec::with_capacity(events.len() + 2 * ranks.len() + 1);
    out.push(obj([
        ("name", Json::Str("process_name".into())),
        ("ph", Json::Str("M".into())),
        ("pid", Json::num(0.0)),
        ("tid", Json::num(0.0)),
        ("args", obj([("name", Json::Str("hecate".into()))])),
    ]));
    for &r in &ranks {
        let rows =
            [(r, format!("rank {r}")), (r + COMM_TID_OFFSET, format!("rank {r} comm"))];
        for (tid, label) in rows {
            out.push(obj([
                ("name", Json::Str("thread_name".into())),
                ("ph", Json::Str("M".into())),
                ("pid", Json::num(0.0)),
                ("tid", Json::num(tid as f64)),
                ("args", obj([("name", Json::Str(label))])),
            ]));
            out.push(obj([
                ("name", Json::Str("thread_sort_index".into())),
                ("ph", Json::Str("M".into())),
                ("pid", Json::num(0.0)),
                ("tid", Json::num(tid as f64)),
                ("args", obj([("sort_index", Json::num(tid as f64))])),
            ]));
        }
    }
    for e in events {
        let tid =
            if e.phase.kind() == Kind::Comm { e.rank + COMM_TID_OFFSET } else { e.rank };
        out.push(obj([
            ("name", Json::Str(e.phase.as_str().into())),
            ("cat", Json::Str(e.phase.kind().as_str().into())),
            ("ph", Json::Str("X".into())),
            ("ts", Json::num(e.ts_us)),
            ("dur", Json::num(e.dur_us)),
            ("pid", Json::num(0.0)),
            ("tid", Json::num(tid as f64)),
            (
                "args",
                obj([
                    ("iter", Json::num(e.iter as f64)),
                    ("layer", Json::num(e.layer as f64)),
                    ("detail", Json::num(e.detail as f64)),
                ]),
            ),
        ]));
    }
    out.extend(counters.iter().cloned());
    obj([("traceEvents", Json::Arr(out)), ("displayTimeUnit", Json::Str("ms".into()))])
}

/// Render step-meter samples as Chrome-trace counter rows (`ph: "C"`):
/// one `resident_bytes rank N` / `pool_idle_bytes rank N` track per rank
/// from the memory ledger, plus global `imbalance` / `predictor_mae`
/// tracks from the load observatory. Counter tracks are keyed by name in
/// Perfetto, so the rank is embedded in the track name.
pub fn counter_rows(
    mem: &[crate::metrics::meter::MemSample],
    load: &[crate::metrics::meter::LoadSample],
) -> Vec<Json> {
    let row = |name: String, tid: u32, ts: f64, key: &'static str, v: f64| {
        obj([
            ("name", Json::Str(name)),
            ("ph", Json::Str("C".into())),
            ("pid", Json::num(0.0)),
            ("tid", Json::num(tid as f64)),
            ("ts", Json::num(ts)),
            ("args", obj([(key, Json::num(v))])),
        ])
    };
    let mut out = Vec::with_capacity(2 * mem.len() + 2 * load.len());
    for s in mem {
        out.push(row(
            format!("resident_bytes rank {}", s.rank),
            s.rank,
            s.ts_us,
            "bytes",
            s.resident_bytes as f64,
        ));
        out.push(row(
            format!("pool_idle_bytes rank {}", s.rank),
            s.rank,
            s.ts_us,
            "bytes",
            s.pool_idle_bytes as f64,
        ));
    }
    for s in load {
        out.push(row("imbalance".to_string(), 0, s.ts_us, "ratio", s.imbalance));
        out.push(row("predictor_mae".to_string(), 0, s.ts_us, "mae", s.mae));
    }
    out
}

/// Write the Chrome-trace document for `events` to `path` (overwrites).
pub fn write_chrome_trace(path: &Path, events: &[Event]) -> anyhow::Result<()> {
    std::fs::write(path, chrome_trace(events).to_string())?;
    Ok(())
}

/// Append `events` to a JSONL stream at `path` (one canonical JSON object
/// per line), creating the file if needed.
pub fn append_jsonl(path: &Path, events: &[Event]) -> anyhow::Result<()> {
    let mut f = std::fs::OpenOptions::new().create(true).append(true).open(path)?;
    let mut buf = String::new();
    for e in events {
        buf.push_str(&e.to_json().to_string());
        buf.push('\n');
    }
    f.write_all(buf.as_bytes())?;
    Ok(())
}

/// [`StepObserver`] that drains the engine's accumulated trace at every
/// span boundary into a directory: new events are appended to
/// [`EVENTS_FILE`], and [`CHROME_TRACE_FILE`] is rewritten with the full
/// timeline so it is loadable at any point during the run.
#[derive(Debug)]
pub struct TraceWriter {
    dir: PathBuf,
    seen: usize,
}

impl TraceWriter {
    pub fn new(dir: impl Into<PathBuf>) -> TraceWriter {
        TraceWriter { dir: dir.into(), seen: 0 }
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Number of events exported so far.
    pub fn exported(&self) -> usize {
        self.seen
    }

    fn flush(&mut self, events: &[Event], counters: &[Json]) -> anyhow::Result<()> {
        std::fs::create_dir_all(&self.dir)?;
        let jsonl = self.dir.join(EVENTS_FILE);
        if self.seen == 0 && jsonl.exists() {
            // fresh run into a reused directory: start the stream over
            std::fs::remove_file(&jsonl)?;
        }
        append_jsonl(&jsonl, &events[self.seen..])?;
        self.seen = events.len();
        let doc = chrome_trace_with_counters(events, counters);
        std::fs::write(self.dir.join(CHROME_TRACE_FILE), doc.to_string())?;
        Ok(())
    }
}

impl StepObserver for TraceWriter {
    fn on_span_end(&mut self, ctx: &SpanCtx<'_>) {
        if let Some(events) = ctx.trace_events() {
            // when the run is also metered, render memory/load counter
            // tracks next to the spans
            let counters = ctx
                .meter_samples()
                .map(|m| counter_rows(m.mem_samples(), m.load_samples()))
                .unwrap_or_default();
            if let Err(e) = self.flush(events, &counters) {
                crate::log_warn!("trace export to {} failed: {e}", self.dir.display());
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(phase: Phase, rank: u32, ts: f64, dur: f64) -> Event {
        Event { phase, iter: 0, layer: 0, rank, ts_us: ts, dur_us: dur, detail: 7 }
    }

    #[test]
    fn phase_names_round_trip() {
        for p in Phase::ALL {
            assert_eq!(Phase::parse(p.as_str()), Some(p), "{p:?}");
        }
        assert_eq!(Phase::parse("bogus"), None);
    }

    #[test]
    fn recorder_spans_are_nonnegative_and_tagged() {
        let mut r = TraceRecorder::new(3);
        let t0 = Instant::now();
        r.span_from(Phase::Gate, 5, 2, t0, 0);
        r.span_from(Phase::ExpertFwd, 5, 2, Instant::now(), 64);
        assert_eq!(r.len(), 2);
        let ev = r.events();
        assert_eq!(ev[0].rank, 3);
        assert_eq!((ev[0].iter, ev[0].layer), (5, 2));
        for e in ev {
            assert!(e.ts_us >= 0.0 && e.dur_us >= 0.0, "{e:?}");
        }
        // recorded end-to-end: second span starts no earlier than the first
        assert!(ev[1].ts_us >= ev[0].ts_us);
    }

    #[test]
    fn shared_epoch_aligns_ranks_and_absorb_merges() {
        let epoch = Instant::now();
        let mut a = TraceRecorder::with_epoch(epoch, 0);
        let mut b = TraceRecorder::with_epoch(epoch, 1);
        let t0 = Instant::now();
        a.span_from(Phase::Gate, 0, 0, t0, 0);
        b.span_from(Phase::Gate, 0, 0, t0, 0);
        let (ta, tb) = (a.events()[0].ts_us, b.events()[0].ts_us);
        assert!((ta - tb).abs() < 1.0, "same start, same epoch: {ta} vs {tb}");
        a.absorb(b);
        assert_eq!(a.len(), 2);
        assert_eq!(a.events()[1].rank, 1);
    }

    #[test]
    fn event_json_round_trips() {
        let e = Event {
            phase: Phase::RecvChunk,
            iter: 9,
            layer: 2,
            rank: 4,
            ts_us: 1234.5,
            dur_us: 67.25,
            detail: 4096,
        };
        let text = e.to_json().to_string();
        let back = Event::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back, e);
    }

    #[test]
    fn chrome_trace_has_one_phase_row_per_rank() {
        let events = vec![
            ev(Phase::Gate, 0, 0.0, 10.0),
            ev(Phase::ExpertFwd, 1, 5.0, 20.0),
            ev(Phase::SendChunk, 1, 6.0, 1.0),
            ev(Phase::Gate, 2, 0.0, 10.0),
        ];
        let doc = chrome_trace(&events);
        let parsed = Json::parse(&doc.to_string()).unwrap();
        let arr = parsed.req("traceEvents").unwrap().as_arr().unwrap().to_vec();
        let mut phase_tids = BTreeSet::new();
        let mut comm_tids = BTreeSet::new();
        for item in &arr {
            if item.get("ph").and_then(|p| p.as_str()) != Some("X") {
                continue;
            }
            let tid = item.req("tid").unwrap().as_f64().unwrap() as u32;
            if tid >= COMM_TID_OFFSET {
                comm_tids.insert(tid);
            } else {
                phase_tids.insert(tid);
            }
            assert!(item.get("args").and_then(|a| a.get("iter")).is_some());
        }
        assert_eq!(phase_tids, BTreeSet::from([0, 1, 2]));
        assert_eq!(comm_tids, BTreeSet::from([1 + COMM_TID_OFFSET]));
        // rank metadata rows exist for every rank
        let names: Vec<&Json> = arr
            .iter()
            .filter(|i| i.get("name").and_then(|n| n.as_str()) == Some("thread_name"))
            .collect();
        assert_eq!(names.len(), 6, "phase + comm row names for 3 ranks");
    }

    #[test]
    fn counter_rows_render_ph_c_tracks_next_to_spans() {
        let mut m = crate::metrics::meter::StepMeter::new(0);
        m.sample_mem(0, 0, 1, 4480, 64, 0);
        m.sample_load(0, 0, &[0.25; 4], &[0.4, 0.3, 0.2, 0.1]);
        let counters = counter_rows(m.mem_samples(), m.load_samples());
        assert_eq!(counters.len(), 4, "2 mem tracks + 2 load tracks per sample");
        let doc = chrome_trace_with_counters(&[ev(Phase::Gate, 1, 0.0, 10.0)], &counters);
        let parsed = Json::parse(&doc.to_string()).unwrap();
        let arr = parsed.req("traceEvents").unwrap().as_arr().unwrap().to_vec();
        let c_rows: Vec<&Json> = arr
            .iter()
            .filter(|i| i.get("ph").and_then(|p| p.as_str()) == Some("C"))
            .collect();
        assert_eq!(c_rows.len(), 4);
        let resident = c_rows
            .iter()
            .find(|i| i.get("name").and_then(|n| n.as_str()) == Some("resident_bytes rank 1"))
            .expect("per-rank resident track");
        assert_eq!(
            resident.get("args").and_then(|a| a.get("bytes")).and_then(|b| b.as_f64()),
            Some(4480.0)
        );
        assert!(c_rows
            .iter()
            .any(|i| i.get("name").and_then(|n| n.as_str()) == Some("imbalance")));
        // span rows are untouched
        assert_eq!(
            arr.iter()
                .filter(|i| i.get("ph").and_then(|p| p.as_str()) == Some("X"))
                .count(),
            1
        );
    }

    #[test]
    fn jsonl_export_appends_and_parses() {
        let dir = std::env::temp_dir().join(format!("hecate-trace-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join(EVENTS_FILE);
        let _ = std::fs::remove_file(&path);
        append_jsonl(&path, &[ev(Phase::Gate, 0, 0.0, 1.0)]).unwrap();
        append_jsonl(&path, &[ev(Phase::Adam, 1, 2.0, 3.0)]).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let events: Vec<Event> = text
            .lines()
            .map(|l| Event::from_json(&Json::parse(l).unwrap()).unwrap())
            .collect();
        assert_eq!(events.len(), 2);
        assert_eq!(events[1].phase, Phase::Adam);
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
