//! Metrics export & reporting: the file-format side of the step meter.
//!
//! [`MetricsWriter`] is the metrics twin of [`TraceWriter`]: a
//! [`StepObserver`] that drains the engine's accumulated
//! [`StepMeter`] samples at every span boundary into a `--metrics-out`
//! directory as three artifacts:
//!
//! * [`METRICS_JSONL_FILE`] — the raw time series, one canonical JSON
//!   object per line: a `kind: "meta"` header (run shape, so offline
//!   consumers can price the analytic [`MemModel`] baselines), then
//!   `kind: "mem"` / `kind: "load"` sample records appended
//!   incrementally.
//! * [`METRICS_PROM_FILE`] — a Prometheus text exposition rewritten per
//!   span from a typed [`Registry`]: per-`(rank, layer)` peak-resident
//!   gauges, per-rank pool gauges, sample counters, and an imbalance
//!   histogram.
//! * [`COUNTERS_FILE`] — a standalone Chrome-trace document holding only
//!   the `ph: "C"` counter rows ([`counter_rows`]), loadable in Perfetto
//!   on its own or next to the `--trace-out` span timeline.
//!
//! [`load_metrics`] + [`MetricsLog`] are the offline pass behind
//! `hecate metrics report DIR`: parse the JSONL back, render the
//! per-rank peak-memory table (measured ledger vs the analytic
//! replicated/EP baselines), the predictor-accuracy table, and the
//! imbalance timeline. Errors are typed ([`MetricsIoError`]) so the CLI
//! can exit nonzero with a clear message on missing/empty/truncated
//! directories.
//!
//! [`TraceWriter`]: super::TraceWriter
//! [`StepObserver`]: crate::fssdp::StepObserver
//! [`StepMeter`]: crate::metrics::meter::StepMeter
//! [`MemModel`]: crate::metrics::meter::MemModel
//! [`Registry`]: crate::metrics::registry::Registry
//! [`counter_rows`]: super::counter_rows

use std::collections::BTreeMap;
use std::fmt;
use std::io::Write as _;
use std::path::{Path, PathBuf};

use crate::fssdp::{SpanCtx, StepObserver};
use crate::metrics::meter::{LoadSample, MemModel, MemSample, StepMeter};
use crate::metrics::registry::{labels, Registry};
use crate::util::json::{obj, Json};

/// JSONL time-series file name inside a `--metrics-out` directory.
pub const METRICS_JSONL_FILE: &str = "metrics.jsonl";
/// Prometheus exposition file name inside a `--metrics-out` directory.
pub const METRICS_PROM_FILE: &str = "metrics.prom";
/// Standalone Chrome-trace counter-track file name inside a
/// `--metrics-out` directory.
pub const COUNTERS_FILE: &str = "counters.json";

/// What went wrong loading a metrics directory. Typed so the CLI maps
/// each case to a clear message and a nonzero exit.
#[derive(Debug)]
pub enum MetricsIoError {
    /// The directory does not exist (or is not a directory).
    MissingDir(PathBuf),
    /// The directory exists but holds no [`METRICS_JSONL_FILE`].
    MissingFile(PathBuf),
    /// The JSONL stream exists but contains no sample records.
    Empty(PathBuf),
    /// A line failed to parse (truncated write, foreign file…).
    Parse {
        path: PathBuf,
        line: usize,
        msg: String,
    },
}

impl fmt::Display for MetricsIoError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            MetricsIoError::MissingDir(p) => {
                write!(f, "metrics directory `{}` does not exist", p.display())
            }
            MetricsIoError::MissingFile(p) => {
                write!(
                    f,
                    "`{}` not found — was the run started with --metrics-out?",
                    p.display()
                )
            }
            MetricsIoError::Empty(p) => {
                write!(f, "`{}` contains no metric samples", p.display())
            }
            MetricsIoError::Parse { path, line, msg } => {
                write!(f, "`{}` line {line}: {msg}", path.display())
            }
        }
    }
}

impl std::error::Error for MetricsIoError {}

/// The run shape recorded in the JSONL `meta` header — what the offline
/// report needs to price the analytic [`MemModel`] baselines.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RunMeta {
    pub devices: usize,
    pub layers: usize,
    pub experts: usize,
    /// Floats per expert chunk (bytes = 4×).
    pub chunk_len: usize,
}

impl RunMeta {
    fn to_json(self) -> Json {
        obj([
            ("kind", Json::Str("meta".into())),
            ("devices", Json::num(self.devices as f64)),
            ("layers", Json::num(self.layers as f64)),
            ("experts", Json::num(self.experts as f64)),
            ("chunk_len", Json::num(self.chunk_len as f64)),
        ])
    }

    /// Owned expert chunks of `rank` under the round-robin shard layout
    /// (expert `e` lives on `e % devices`) — the EP baseline's count.
    pub fn shard_chunks(&self, rank: usize) -> usize {
        self.experts / self.devices + usize::from(rank < self.experts % self.devices)
    }
}

/// [`StepObserver`] draining the engine's step meter at every span
/// boundary into a metrics directory (see the module docs for the three
/// artifacts). Inert when the session is not metered.
#[derive(Debug)]
pub struct MetricsWriter {
    dir: PathBuf,
    mem_seen: usize,
    load_seen: usize,
    started: bool,
}

impl MetricsWriter {
    pub fn new(dir: impl Into<PathBuf>) -> MetricsWriter {
        MetricsWriter { dir: dir.into(), mem_seen: 0, load_seen: 0, started: false }
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Samples exported so far (both ledgers).
    pub fn exported(&self) -> usize {
        self.mem_seen + self.load_seen
    }

    fn flush(&mut self, meta: RunMeta, meter: &StepMeter) -> anyhow::Result<()> {
        std::fs::create_dir_all(&self.dir)?;
        let jsonl = self.dir.join(METRICS_JSONL_FILE);
        if !self.started {
            // fresh run into a reused directory: restart the stream, and
            // lead with the meta header offline consumers key off
            let _ = std::fs::remove_file(&jsonl);
            append_lines(&jsonl, std::iter::once(meta.to_json()))?;
            self.started = true;
        }
        let mem = meter.mem_samples();
        let load = meter.load_samples();
        append_lines(&jsonl, mem[self.mem_seen..].iter().map(mem_to_json))?;
        append_lines(&jsonl, load[self.load_seen..].iter().map(load_to_json))?;
        self.mem_seen = mem.len();
        self.load_seen = load.len();

        // full-history artifacts are rewritten so they are loadable at
        // any point during the run (same policy as the Chrome trace)
        let reg = build_registry(meta, mem, load);
        std::fs::write(self.dir.join(METRICS_PROM_FILE), reg.to_prometheus())?;
        let counters = super::counter_rows(mem, load);
        let doc = super::chrome_trace_with_counters(&[], &counters);
        std::fs::write(self.dir.join(COUNTERS_FILE), doc.to_string())?;
        Ok(())
    }
}

impl StepObserver for MetricsWriter {
    fn on_span_end(&mut self, ctx: &SpanCtx<'_>) {
        if let Some(meter) = ctx.meter_samples() {
            let e = ctx.engine();
            let meta = RunMeta {
                devices: e.topo.num_devices(),
                layers: e.num_layers(),
                experts: e.dims.experts,
                chunk_len: e.dims.chunk_len(),
            };
            if let Err(err) = self.flush(meta, meter) {
                crate::log_warn!("metrics export to {} failed: {err}", self.dir.display());
            }
        }
    }
}

fn append_lines(path: &Path, rows: impl Iterator<Item = Json>) -> anyhow::Result<()> {
    let mut f = std::fs::OpenOptions::new().create(true).append(true).open(path)?;
    let mut buf = String::new();
    for row in rows {
        buf.push_str(&row.to_string());
        buf.push('\n');
    }
    f.write_all(buf.as_bytes())?;
    Ok(())
}

fn mem_to_json(s: &MemSample) -> Json {
    obj([
        ("kind", Json::Str("mem".into())),
        ("ts_us", Json::num(s.ts_us)),
        ("iter", Json::num(s.iter as f64)),
        ("layer", Json::num(s.layer as f64)),
        ("rank", Json::num(s.rank as f64)),
        ("resident_bytes", Json::num(s.resident_bytes as f64)),
        ("pool_idle_bytes", Json::num(s.pool_idle_bytes as f64)),
        ("payload_idle_bytes", Json::num(s.payload_idle_bytes as f64)),
    ])
}

fn load_to_json(s: &LoadSample) -> Json {
    obj([
        ("kind", Json::Str("load".into())),
        ("ts_us", Json::num(s.ts_us)),
        ("iter", Json::num(s.iter as f64)),
        ("layer", Json::num(s.layer as f64)),
        ("imbalance", Json::num(s.imbalance)),
        ("entropy", Json::num(s.entropy)),
        ("mae", Json::num(s.mae)),
        ("rank_corr", Json::num(s.rank_corr)),
        ("max_load", Json::num(s.max_load)),
    ])
}

/// Fold the raw samples into the typed registry behind the Prometheus
/// exposition: peak/pool gauges per rank, sample counters, and the
/// imbalance-percent histogram (log-2 buckets want values ≥ 1, so the
/// ratio is scaled by 100).
fn build_registry(meta: RunMeta, mem: &[MemSample], load: &[LoadSample]) -> Registry {
    let mut reg = Registry::new();
    reg.gauge_set("hecate_devices", labels(&[]), meta.devices as f64);
    reg.gauge_set("hecate_layers", labels(&[]), meta.layers as f64);
    reg.gauge_set(
        "hecate_replicated_bytes_per_layer",
        labels(&[]),
        (meta.experts * meta.chunk_len * 4) as f64,
    );
    reg.counter_add("hecate_mem_samples_total", labels(&[]), mem.len() as f64);
    reg.counter_add("hecate_load_samples_total", labels(&[]), load.len() as f64);
    let mut peak: BTreeMap<(u32, u32), u64> = BTreeMap::new();
    let mut pool: BTreeMap<u32, u64> = BTreeMap::new();
    let mut payload: BTreeMap<u32, u64> = BTreeMap::new();
    for s in mem {
        let p = peak.entry((s.rank, s.layer)).or_insert(0);
        *p = (*p).max(s.resident_bytes);
        let p = pool.entry(s.rank).or_insert(0);
        *p = (*p).max(s.pool_idle_bytes);
        let p = payload.entry(s.rank).or_insert(0);
        *p = (*p).max(s.payload_idle_bytes);
    }
    for ((rank, layer), bytes) in &peak {
        let l = labels(&[("rank", &rank.to_string()), ("layer", &layer.to_string())]);
        reg.gauge_set("hecate_peak_resident_bytes", l, *bytes as f64);
    }
    for (rank, bytes) in &pool {
        let l = labels(&[("rank", &rank.to_string())]);
        reg.gauge_set("hecate_pool_idle_bytes", l, *bytes as f64);
    }
    for (rank, bytes) in &payload {
        let l = labels(&[("rank", &rank.to_string())]);
        reg.gauge_set("hecate_payload_idle_bytes", l, *bytes as f64);
    }
    for s in load {
        reg.histogram_observe("hecate_imbalance_pct", labels(&[]), s.imbalance * 100.0);
        let l = labels(&[("layer", &s.layer.to_string())]);
        reg.gauge_set("hecate_predictor_mae", l.clone(), s.mae);
        reg.gauge_set("hecate_predictor_rank_corr", l, s.rank_corr);
    }
    reg
}

/// A metrics directory parsed back into memory: the `meta` header plus
/// both sample ledgers, ready for report rendering.
#[derive(Debug, Clone)]
pub struct MetricsLog {
    pub meta: RunMeta,
    pub mem: Vec<MemSample>,
    pub load: Vec<LoadSample>,
}

/// Parse `dir`'s [`METRICS_JSONL_FILE`] back into a [`MetricsLog`].
pub fn load_metrics(dir: &Path) -> Result<MetricsLog, MetricsIoError> {
    if !dir.is_dir() {
        return Err(MetricsIoError::MissingDir(dir.to_path_buf()));
    }
    let path = dir.join(METRICS_JSONL_FILE);
    let text = std::fs::read_to_string(&path)
        .map_err(|_| MetricsIoError::MissingFile(path.clone()))?;
    let mut meta: Option<RunMeta> = None;
    let mut mem = Vec::new();
    let mut load = Vec::new();
    for (i, line) in text.lines().enumerate() {
        if line.trim().is_empty() {
            continue;
        }
        let err = |msg: String| MetricsIoError::Parse {
            path: path.clone(),
            line: i + 1,
            msg,
        };
        let j = Json::parse(line).map_err(|e| err(e.to_string()))?;
        let num = |key: &str| -> Result<f64, MetricsIoError> {
            j.get(key)
                .and_then(|v| v.as_f64())
                .ok_or_else(|| err(format!("missing numeric field `{key}`")))
        };
        match j.get("kind").and_then(|k| k.as_str()) {
            Some("meta") => {
                meta = Some(RunMeta {
                    devices: num("devices")? as usize,
                    layers: num("layers")? as usize,
                    experts: num("experts")? as usize,
                    chunk_len: num("chunk_len")? as usize,
                });
            }
            Some("mem") => mem.push(MemSample {
                ts_us: num("ts_us")?,
                iter: num("iter")? as u32,
                layer: num("layer")? as u32,
                rank: num("rank")? as u32,
                resident_bytes: num("resident_bytes")? as u64,
                pool_idle_bytes: num("pool_idle_bytes")? as u64,
                payload_idle_bytes: num("payload_idle_bytes")? as u64,
            }),
            Some("load") => load.push(LoadSample {
                ts_us: num("ts_us")?,
                iter: num("iter")? as u32,
                layer: num("layer")? as u32,
                imbalance: num("imbalance")?,
                entropy: num("entropy")?,
                mae: num("mae")?,
                rank_corr: num("rank_corr")?,
                max_load: num("max_load")?,
            }),
            Some(other) => return Err(err(format!("unknown record kind `{other}`"))),
            None => return Err(err("record has no `kind` field".to_string())),
        }
    }
    let meta = meta.ok_or_else(|| MetricsIoError::Parse {
        path: path.clone(),
        line: 1,
        msg: "no `meta` header record".to_string(),
    })?;
    if mem.is_empty() && load.is_empty() {
        return Err(MetricsIoError::Empty(path));
    }
    Ok(MetricsLog { meta, mem, load })
}

impl MetricsLog {
    /// Per-`(rank, layer)` peak resident bytes from the ledger.
    pub fn high_water(&self) -> BTreeMap<(u32, u32), u64> {
        let mut hw = BTreeMap::new();
        for s in &self.mem {
            let e = hw.entry((s.rank, s.layer)).or_insert(0u64);
            *e = (*e).max(s.resident_bytes);
        }
        hw
    }

    /// The peak-memory table: per rank, the measured peak resident bytes
    /// (worst layer) next to the analytic replicated and EP baselines.
    pub fn peak_memory_table(&self) -> String {
        let hw = self.high_water();
        let mut out = String::new();
        out.push_str("peak memory (per rank, worst layer)\n");
        out.push_str(&format!(
            "{:>5} {:>14} {:>16} {:>10} {:>12}\n",
            "rank", "peak_bytes", "replicated_bytes", "ep_bytes", "vs_replicated"
        ));
        for rank in 0..self.meta.devices {
            let peak = (0..self.meta.layers)
                .map(|l| hw.get(&(rank as u32, l as u32)).copied().unwrap_or(0))
                .max()
                .unwrap_or(0);
            let model = MemModel::per_device(
                0, // placement chunks come from the ledger, not the model
                self.meta.shard_chunks(rank),
                self.meta.experts,
                self.meta.chunk_len,
            );
            let ratio = if model.replicated_bytes > 0 {
                peak as f64 / model.replicated_bytes as f64
            } else {
                0.0
            };
            out.push_str(&format!(
                "{:>5} {:>14} {:>16} {:>10} {:>11.2}x\n",
                rank, peak, model.replicated_bytes, model.ep_bytes, ratio
            ));
        }
        out
    }

    /// The predictor-accuracy table: per layer, mean/final MAE and mean
    /// rank-order correlation across the recorded load samples.
    pub fn predictor_table(&self) -> String {
        let mut out = String::new();
        out.push_str("predictor accuracy (per layer)\n");
        out.push_str(&format!(
            "{:>5} {:>8} {:>10} {:>10} {:>10}\n",
            "layer", "samples", "mean_mae", "final_mae", "rank_corr"
        ));
        for layer in 0..self.meta.layers {
            let rows: Vec<&LoadSample> =
                self.load.iter().filter(|s| s.layer == layer as u32).collect();
            if rows.is_empty() {
                continue;
            }
            let n = rows.len() as f64;
            let mean_mae = rows.iter().map(|s| s.mae).sum::<f64>() / n;
            let mean_corr = rows.iter().map(|s| s.rank_corr).sum::<f64>() / n;
            let final_mae = rows.last().map(|s| s.mae).unwrap_or(0.0);
            out.push_str(&format!(
                "{:>5} {:>8} {:>10.4} {:>10.4} {:>10.3}\n",
                layer,
                rows.len(),
                mean_mae,
                final_mae,
                mean_corr
            ));
        }
        out
    }

    /// The imbalance timeline: one row per `(iter, layer)` load sample.
    pub fn imbalance_timeline(&self) -> String {
        let mut out = String::new();
        out.push_str("imbalance timeline\n");
        out.push_str(&format!(
            "{:>5} {:>5} {:>10} {:>9} {:>9}\n",
            "iter", "layer", "imbalance", "entropy", "max_load"
        ));
        for s in &self.load {
            out.push_str(&format!(
                "{:>5} {:>5} {:>10.3} {:>9.3} {:>9.3}\n",
                s.iter, s.layer, s.imbalance, s.entropy, s.max_load
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fssdp::{Session, SessionConfig};
    use crate::metrics::registry::parse_prometheus;
    use crate::topology::Topology;

    fn tmp(tag: &str) -> PathBuf {
        std::env::temp_dir().join(format!("hecate-mio-{tag}-{}", std::process::id()))
    }

    #[test]
    fn writer_exports_all_three_files_and_the_report_loads() {
        let dir = tmp("rt");
        let _ = std::fs::remove_dir_all(&dir);
        let cfg = SessionConfig::builder()
            .reference()
            .topology(Topology::cluster_a(2, 2))
            .layers(2)
            .data_shards(4)
            .seed(11)
            .metrics(true)
            .build()
            .unwrap();
        let mut s = Session::fresh(cfg).unwrap();
        let mut w = MetricsWriter::new(&dir);
        s.run_observed(3, &mut [&mut w]).unwrap();
        assert_eq!(w.exported(), 3 * 2 * 4 + 3 * 2, "mem + load samples");

        let log = load_metrics(&dir).unwrap();
        assert_eq!(log.meta.devices, 4);
        assert_eq!(log.meta.layers, 2);
        assert_eq!(log.mem.len(), 3 * 2 * 4);
        assert_eq!(log.load.len(), 3 * 2);
        // the parsed ledger is the in-memory ledger
        assert_eq!(log.mem, s.meter_samples().unwrap().mem_samples());
        assert_eq!(log.high_water(), s.meter_samples().unwrap().high_water());

        // the exposition round-trips through the parser and agrees with
        // the ledger's high-water marks
        let text = std::fs::read_to_string(dir.join(METRICS_PROM_FILE)).unwrap();
        let samples = parse_prometheus(&text).unwrap();
        let hw = log.high_water();
        for ((rank, layer), bytes) in &hw {
            let found = samples
                .iter()
                .find(|p| {
                    p.name == "hecate_peak_resident_bytes"
                        && p.labels.get("rank").map(String::as_str)
                            == Some(rank.to_string().as_str())
                        && p.labels.get("layer").map(String::as_str)
                            == Some(layer.to_string().as_str())
                })
                .expect("peak gauge per (rank, layer)");
            assert_eq!(found.value, *bytes as f64);
        }

        // counters.json is a loadable chrome doc made of ph:"C" rows
        let doc = std::fs::read_to_string(dir.join(COUNTERS_FILE)).unwrap();
        let parsed = Json::parse(&doc).unwrap();
        let rows = parsed.req("traceEvents").unwrap().as_arr().unwrap().to_vec();
        assert!(rows
            .iter()
            .any(|r| r.get("ph").and_then(|p| p.as_str()) == Some("C")));

        // the three report tables render and carry the headline numbers
        let peak = log.peak_memory_table();
        assert!(peak.contains("replicated_bytes"), "{peak}");
        let pred = log.predictor_table();
        assert!(pred.contains("mean_mae"), "{pred}");
        let tl = log.imbalance_timeline();
        assert_eq!(tl.lines().count(), 2 + 3 * 2, "header rows + one per sample");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn load_metrics_reports_typed_errors() {
        let missing = tmp("missing");
        let _ = std::fs::remove_dir_all(&missing);
        match load_metrics(&missing) {
            Err(MetricsIoError::MissingDir(_)) => {}
            other => panic!("expected MissingDir, got {other:?}"),
        }

        let dir = tmp("nofile");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        match load_metrics(&dir) {
            Err(MetricsIoError::MissingFile(_)) => {}
            other => panic!("expected MissingFile, got {other:?}"),
        }

        // a meta header with no samples is Empty
        std::fs::write(
            dir.join(METRICS_JSONL_FILE),
            "{\"kind\":\"meta\",\"devices\":4,\"layers\":1,\"experts\":8,\"chunk_len\":280}\n",
        )
        .unwrap();
        match load_metrics(&dir) {
            Err(MetricsIoError::Empty(_)) => {}
            other => panic!("expected Empty, got {other:?}"),
        }

        // a truncated line is a Parse error naming the line
        std::fs::write(
            dir.join(METRICS_JSONL_FILE),
            "{\"kind\":\"meta\",\"devices\":4,\"layers\":1,\"experts\":8,\"chunk_len\":280}\n{\"kind\":\"mem\",\"ts_us\":1.0,\"it",
        )
        .unwrap();
        match load_metrics(&dir) {
            Err(MetricsIoError::Parse { line, .. }) => assert_eq!(line, 2),
            other => panic!("expected Parse, got {other:?}"),
        }
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn run_meta_shard_chunks_round_robin() {
        let m = RunMeta { devices: 4, layers: 1, experts: 10, chunk_len: 280 };
        // experts 0..10 round-robin over 4 devices: 3,3,2,2
        assert_eq!((0..4).map(|r| m.shard_chunks(r)).collect::<Vec<_>>(), vec![3, 3, 2, 2]);
        assert_eq!((0..4).map(|r| m.shard_chunks(r)).sum::<usize>(), 10);
    }
}
