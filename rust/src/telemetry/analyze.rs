//! Offline trace analysis: per-step critical path, §4.3 overlap
//! efficiency, and the per-rank straggler report.
//!
//! Definitions (pinned by the synthetic-trace tests below):
//!
//! - **busy time** of a rank = Σ durations of its on-thread spans
//!   ([`Kind::Compute`] + [`Kind::CommWait`]); wire-level [`Kind::Comm`]
//!   events are bookkeeping and excluded.
//! - **critical path** of a step = the rank with the largest busy time in
//!   that iteration; the step's wall time is `max(end) − min(start)` over
//!   all of the iteration's events.
//! - **overlap efficiency** = `1 − exposed / wire`, clamped to `[0, 1]`:
//!   `wire` is the total modeled in-flight time of delivered expert-chunk
//!   payloads ([`Phase::RecvChunk`] durations — the α–β pacing estimate),
//!   `exposed` is the time ranks actually sat blocked on the sparse
//!   collectives ([`Phase::SpagWait`] + [`Phase::SprsWait`] +
//!   [`Phase::Materialize`]). This is the §4.3 number: the fraction of
//!   communication hidden under compute. Unpaced runs have `wire = 0`
//!   (in-process channels deliver instantly) and report `None`.
//! - **straggler report** — per rank: compute, wait, idle
//!   (`span − compute − wait`, clamped at 0), token rows processed
//!   ([`Phase::ExpertFwd`] `detail`), and skew = compute ÷ median
//!   compute across ranks (realized-load imbalance shows up here).

use std::collections::{BTreeMap, BTreeSet};
use std::path::Path;

use super::{Event, Kind, Phase, EVENTS_FILE};
use crate::metrics::Table;
use crate::util::json::Json;

/// Critical-path summary of one training step.
#[derive(Debug, Clone, PartialEq)]
pub struct StepReport {
    pub iter: u32,
    /// `max(end) − min(start)` over the step's events, µs.
    pub wall_us: f64,
    /// Rank with the largest busy time this step.
    pub critical_rank: u32,
    /// That rank's busy time, µs.
    pub critical_busy_us: f64,
    /// The phase the critical rank spent most time in.
    pub top_phase: Phase,
}

/// Straggler accounting for one rank over the whole trace.
#[derive(Debug, Clone, PartialEq)]
pub struct RankReport {
    pub rank: u32,
    pub compute_us: f64,
    pub wait_us: f64,
    /// Span time not covered by recorded on-thread phases.
    pub idle_us: f64,
    /// Token rows pushed through expert FFN forward.
    pub tokens: u64,
    /// compute ÷ median compute across ranks (1.0 = perfectly balanced).
    pub skew: f64,
}

/// Full analysis of a recorded trace.
#[derive(Debug, Clone, PartialEq)]
pub struct Analysis {
    pub steps: Vec<StepReport>,
    pub ranks: Vec<RankReport>,
    /// Total modeled in-flight time of expert-chunk deliveries, µs.
    pub wire_us: f64,
    /// Total time ranks sat blocked on the sparse collectives, µs.
    pub exposed_us: f64,
    /// §4.3 fraction of comm hidden under compute; `None` when no wire
    /// time was observed (unpaced run — nothing to hide).
    pub overlap_efficiency: Option<f64>,
    pub max_idle_us: f64,
    pub median_idle_us: f64,
}

fn median(mut xs: Vec<f64>) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.sort_by(f64::total_cmp);
    xs[xs.len() / 2]
}

/// Analyze a recorded event stream (order-insensitive).
pub fn analyze(events: &[Event]) -> Analysis {
    // ---- per-rank accounting ----
    #[derive(Default, Clone)]
    struct RankAcc {
        compute: f64,
        wait: f64,
        tokens: u64,
        first: f64,
        last: f64,
        seen: bool,
    }
    let mut per_rank: BTreeMap<u32, RankAcc> = BTreeMap::new();
    let mut wire_us = 0.0;
    let mut exposed_us = 0.0;
    for e in events {
        if e.phase == Phase::RecvChunk {
            wire_us += e.dur_us;
        }
        if matches!(e.phase, Phase::SpagWait | Phase::SprsWait | Phase::Materialize) {
            exposed_us += e.dur_us;
        }
        if e.phase.kind() == Kind::Comm {
            continue; // wire bookkeeping: not on-thread time
        }
        let acc = per_rank.entry(e.rank).or_default();
        match e.phase.kind() {
            Kind::Compute => acc.compute += e.dur_us,
            Kind::CommWait => acc.wait += e.dur_us,
            Kind::Comm => unreachable!(),
        }
        if e.phase == Phase::ExpertFwd {
            acc.tokens += e.detail;
        }
        let end = e.ts_us + e.dur_us;
        if !acc.seen {
            (acc.first, acc.last, acc.seen) = (e.ts_us, end, true);
        } else {
            acc.first = acc.first.min(e.ts_us);
            acc.last = acc.last.max(end);
        }
    }
    let med_compute = median(per_rank.values().map(|a| a.compute).collect());
    let ranks: Vec<RankReport> = per_rank
        .iter()
        .map(|(&rank, a)| RankReport {
            rank,
            compute_us: a.compute,
            wait_us: a.wait,
            idle_us: ((a.last - a.first) - a.compute - a.wait).max(0.0),
            tokens: a.tokens,
            skew: if med_compute > 0.0 { a.compute / med_compute } else { 1.0 },
        })
        .collect();
    let idles: Vec<f64> = ranks.iter().map(|r| r.idle_us).collect();
    let max_idle_us = idles.iter().cloned().fold(0.0, f64::max);
    let median_idle_us = median(idles);

    // ---- per-step critical path ----
    let iters: BTreeSet<u32> = events.iter().map(|e| e.iter).collect();
    let mut steps = Vec::new();
    for it in iters {
        let mut lo = f64::INFINITY;
        let mut hi = f64::NEG_INFINITY;
        // busy time and per-phase sums, per rank, this iteration only
        let mut busy: BTreeMap<u32, f64> = BTreeMap::new();
        let mut by_phase: BTreeMap<(u32, Phase), f64> = BTreeMap::new();
        for e in events.iter().filter(|e| e.iter == it) {
            lo = lo.min(e.ts_us);
            hi = hi.max(e.ts_us + e.dur_us);
            if e.phase.kind() != Kind::Comm {
                *busy.entry(e.rank).or_default() += e.dur_us;
                *by_phase.entry((e.rank, e.phase)).or_default() += e.dur_us;
            }
        }
        let Some((&critical_rank, &critical_busy_us)) =
            busy.iter().max_by(|a, b| a.1.total_cmp(b.1))
        else {
            continue; // iteration with only comm events — nothing to rank
        };
        let top_phase = by_phase
            .iter()
            .filter(|((r, _), _)| *r == critical_rank)
            .max_by(|a, b| a.1.total_cmp(b.1))
            .map(|((_, p), _)| *p)
            .unwrap_or(Phase::Plan);
        steps.push(StepReport {
            iter: it,
            wall_us: (hi - lo).max(0.0),
            critical_rank,
            critical_busy_us,
            top_phase,
        });
    }

    let overlap_efficiency =
        if wire_us > 0.0 { Some((1.0 - exposed_us / wire_us).clamp(0.0, 1.0)) } else { None };
    Analysis {
        steps,
        ranks,
        wire_us,
        exposed_us,
        overlap_efficiency,
        max_idle_us,
        median_idle_us,
    }
}

/// What went wrong loading a trace directory. Typed so `hecate trace
/// analyze` maps each case to a clear message and a nonzero exit instead
/// of an opaque I/O error.
#[derive(Debug)]
pub enum AnalyzeError {
    /// The directory does not exist (or is not a directory).
    MissingDir(std::path::PathBuf),
    /// The directory exists but holds no [`EVENTS_FILE`].
    MissingFile(std::path::PathBuf),
    /// The event stream exists but contains no events.
    Empty(std::path::PathBuf),
    /// A line failed to parse (truncated write, foreign file…).
    Parse {
        path: std::path::PathBuf,
        line: usize,
        msg: String,
    },
}

impl std::fmt::Display for AnalyzeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            AnalyzeError::MissingDir(p) => {
                write!(f, "trace directory `{}` does not exist", p.display())
            }
            AnalyzeError::MissingFile(p) => {
                write!(
                    f,
                    "`{}` not found — run `hecate fssdp --trace-out` first",
                    p.display()
                )
            }
            AnalyzeError::Empty(p) => {
                write!(f, "`{}` contains no trace events", p.display())
            }
            AnalyzeError::Parse { path, line, msg } => {
                write!(f, "`{}` line {line}: {msg}", path.display())
            }
        }
    }
}

impl std::error::Error for AnalyzeError {}

/// Load the JSONL event stream from a `--trace-out` directory.
/// Missing/empty/truncated inputs come back as typed [`AnalyzeError`]s.
pub fn load_events(dir: &Path) -> Result<Vec<Event>, AnalyzeError> {
    if !dir.is_dir() {
        return Err(AnalyzeError::MissingDir(dir.to_path_buf()));
    }
    let path = dir.join(EVENTS_FILE);
    let text = std::fs::read_to_string(&path)
        .map_err(|_| AnalyzeError::MissingFile(path.clone()))?;
    let mut events = Vec::new();
    for (i, line) in text.lines().enumerate() {
        if line.trim().is_empty() {
            continue;
        }
        let parse_err = |msg: String| AnalyzeError::Parse {
            path: path.clone(),
            line: i + 1,
            msg,
        };
        let j = Json::parse(line).map_err(|e| parse_err(e.to_string()))?;
        events.push(Event::from_json(&j).map_err(|e| parse_err(e.to_string()))?);
    }
    if events.is_empty() {
        return Err(AnalyzeError::Empty(path));
    }
    Ok(events)
}

/// [`load_events`] + [`analyze`].
pub fn analyze_dir(dir: &Path) -> anyhow::Result<Analysis> {
    Ok(analyze(&load_events(dir)?))
}

impl Analysis {
    /// Overlap efficiency as a percentage, when defined.
    pub fn overlap_pct(&self) -> Option<f64> {
        self.overlap_efficiency.map(|f| f * 100.0)
    }

    /// Largest compute skew across ranks (straggler factor).
    pub fn max_skew(&self) -> f64 {
        self.ranks.iter().map(|r| r.skew).fold(1.0, f64::max)
    }

    /// Per-step critical-path table.
    pub fn steps_table(&self) -> Table {
        let mut t = Table::new(&[
            "iter", "wall_ms", "critical_rank", "critical_busy_ms", "top_phase",
        ]);
        for s in &self.steps {
            t.row(vec![
                s.iter.to_string(),
                format!("{:.3}", s.wall_us / 1e3),
                s.critical_rank.to_string(),
                format!("{:.3}", s.critical_busy_us / 1e3),
                s.top_phase.as_str().to_string(),
            ]);
        }
        t
    }

    /// Per-rank straggler table.
    pub fn straggler_table(&self) -> Table {
        let mut t =
            Table::new(&["rank", "compute_ms", "wait_ms", "idle_ms", "tokens", "skew"]);
        for r in &self.ranks {
            t.row(vec![
                r.rank.to_string(),
                format!("{:.3}", r.compute_us / 1e3),
                format!("{:.3}", r.wait_us / 1e3),
                format!("{:.3}", r.idle_us / 1e3),
                r.tokens.to_string(),
                format!("{:.2}", r.skew),
            ]);
        }
        t
    }

    /// One-line headline: overlap efficiency + idle spread.
    pub fn summary(&self) -> String {
        let overlap = match self.overlap_pct() {
            Some(p) => format!(
                "overlap efficiency {p:.1}% (wire {:.3} ms, exposed {:.3} ms)",
                self.wire_us / 1e3,
                self.exposed_us / 1e3
            ),
            None => "overlap efficiency n/a (no paced wire time recorded)".to_string(),
        };
        format!(
            "{overlap}; idle max {:.3} ms / median {:.3} ms; max skew {:.2}",
            self.max_idle_us / 1e3,
            self.median_idle_us / 1e3,
            self.max_skew()
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(phase: Phase, iter: u32, rank: u32, ts: f64, dur: f64, detail: u64) -> Event {
        Event { phase, iter, layer: 0, rank, ts_us: ts, dur_us: dur, detail }
    }

    #[test]
    fn empty_trace_is_fine() {
        let a = analyze(&[]);
        assert!(a.steps.is_empty() && a.ranks.is_empty());
        assert_eq!(a.overlap_efficiency, None);
        assert_eq!(a.max_skew(), 1.0);
    }

    #[test]
    fn overlap_efficiency_known_answer() {
        // 400 µs of wire time, 100 µs exposed (40 spAG + 60 spRS) → 75 %
        // of the communication was hidden under compute.
        let events = vec![
            ev(Phase::RecvChunk, 0, 0, 0.0, 100.0, 1024),
            ev(Phase::RecvChunk, 0, 0, 50.0, 300.0, 1024),
            ev(Phase::SpagWait, 0, 0, 10.0, 40.0, 0),
            ev(Phase::SprsWait, 0, 0, 200.0, 60.0, 0),
            ev(Phase::ExpertFwd, 0, 0, 60.0, 120.0, 64),
        ];
        let a = analyze(&events);
        assert_eq!(a.wire_us, 400.0);
        assert_eq!(a.exposed_us, 100.0);
        assert!((a.overlap_efficiency.unwrap() - 0.75).abs() < 1e-12);
        // exposed > wire clamps at 0 instead of going negative
        let worst = analyze(&[
            ev(Phase::RecvChunk, 0, 0, 0.0, 10.0, 1024),
            ev(Phase::SpagWait, 0, 0, 0.0, 50.0, 0),
        ]);
        assert_eq!(worst.overlap_efficiency, Some(0.0));
    }

    #[test]
    fn straggler_report_known_answer() {
        // rank 1 computes 2× the median and idles; rank 0 is balanced.
        let events = vec![
            ev(Phase::ExpertFwd, 0, 0, 0.0, 100.0, 32),
            ev(Phase::SpagWait, 0, 0, 100.0, 20.0, 0),
            ev(Phase::ExpertFwd, 0, 1, 0.0, 200.0, 64),
            ev(Phase::SpagWait, 0, 1, 250.0, 10.0, 0), // 50 µs gap → idle
            ev(Phase::ExpertFwd, 0, 2, 0.0, 100.0, 32),
        ];
        let a = analyze(&events);
        assert_eq!(a.ranks.len(), 3);
        let r1 = &a.ranks[1];
        assert_eq!(r1.rank, 1);
        assert_eq!(r1.compute_us, 200.0);
        assert_eq!(r1.wait_us, 10.0);
        assert_eq!(r1.idle_us, 50.0);
        assert_eq!(r1.tokens, 64);
        assert!((r1.skew - 2.0).abs() < 1e-12, "median compute 100 → skew 2");
        assert_eq!(a.max_skew(), 2.0);
        assert_eq!(a.max_idle_us, 50.0);
        assert_eq!(a.ranks[0].idle_us, 0.0);
    }

    #[test]
    fn critical_path_per_step() {
        let events = vec![
            // iter 0: rank 1 is critical (150 µs busy, gate-dominated)
            ev(Phase::ExpertFwd, 0, 0, 0.0, 100.0, 8),
            ev(Phase::Gate, 0, 1, 0.0, 90.0, 0),
            ev(Phase::ExpertFwd, 0, 1, 90.0, 60.0, 8),
            // comm events must not decide the critical rank
            ev(Phase::RecvChunk, 0, 0, 0.0, 500.0, 64),
            // iter 1: rank 0 is critical
            ev(Phase::ExpertFwd, 1, 0, 200.0, 80.0, 8),
            ev(Phase::ExpertFwd, 1, 1, 200.0, 10.0, 8),
        ];
        let a = analyze(&events);
        assert_eq!(a.steps.len(), 2);
        let s0 = &a.steps[0];
        assert_eq!(s0.iter, 0);
        assert_eq!(s0.critical_rank, 1);
        assert_eq!(s0.critical_busy_us, 150.0);
        assert_eq!(s0.top_phase, Phase::Gate);
        assert_eq!(s0.wall_us, 500.0, "wall spans all events incl. comm");
        assert_eq!(a.steps[1].critical_rank, 0);
        assert_eq!(a.steps[1].wall_us, 80.0);
    }

    #[test]
    fn tables_and_summary_render() {
        let events = vec![
            ev(Phase::ExpertFwd, 0, 0, 0.0, 100.0, 8),
            ev(Phase::RecvChunk, 0, 0, 0.0, 50.0, 64),
            ev(Phase::SpagWait, 0, 0, 100.0, 10.0, 0),
        ];
        let a = analyze(&events);
        let md = a.steps_table().to_markdown();
        assert!(md.contains("critical_rank"), "{md}");
        let md = a.straggler_table().to_markdown();
        assert!(md.contains("skew"), "{md}");
        assert!(a.summary().contains("overlap efficiency 80.0%"), "{}", a.summary());
    }

    #[test]
    fn load_events_round_trips_through_dir() {
        let dir =
            std::env::temp_dir().join(format!("hecate-trace-an-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join(EVENTS_FILE);
        let _ = std::fs::remove_file(&path);
        let events =
            vec![ev(Phase::Gate, 0, 0, 0.0, 5.0, 0), ev(Phase::Adam, 0, 1, 5.0, 2.0, 0)];
        super::super::append_jsonl(&path, &events).unwrap();
        let loaded = load_events(&dir).unwrap();
        assert_eq!(loaded, events);
        assert!(analyze_dir(&dir).is_ok());
        std::fs::remove_dir_all(&dir).unwrap();
        assert!(analyze_dir(&dir).is_err(), "missing dir is a clear error");
    }

    #[test]
    fn load_events_reports_typed_errors() {
        let base =
            std::env::temp_dir().join(format!("hecate-trace-err-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&base);

        // directory absent entirely
        match load_events(&base) {
            Err(AnalyzeError::MissingDir(_)) => {}
            other => panic!("expected MissingDir, got {other:?}"),
        }

        // directory present, stream absent
        std::fs::create_dir_all(&base).unwrap();
        match load_events(&base) {
            Err(AnalyzeError::MissingFile(p)) => {
                assert!(p.ends_with(EVENTS_FILE), "{}", p.display())
            }
            other => panic!("expected MissingFile, got {other:?}"),
        }

        // stream present but empty (only blank lines)
        let path = base.join(EVENTS_FILE);
        std::fs::write(&path, "\n\n").unwrap();
        match load_events(&base) {
            Err(AnalyzeError::Empty(_)) => {}
            other => panic!("expected Empty, got {other:?}"),
        }

        // truncated trailing line names the line number
        let good = ev(Phase::Gate, 0, 0, 0.0, 5.0, 0).to_json().to_string();
        std::fs::write(&path, format!("{good}\n{{\"phase\":\"gate\",\"it")).unwrap();
        match load_events(&base) {
            Err(AnalyzeError::Parse { line, .. }) => assert_eq!(line, 2),
            other => panic!("expected Parse, got {other:?}"),
        }

        // a well-formed line with an unknown phase is also a Parse error
        std::fs::write(
            &path,
            "{\"phase\":\"bogus\",\"iter\":0,\"layer\":0,\"rank\":0,\"ts_us\":0,\"dur_us\":0,\"detail\":0}\n",
        )
        .unwrap();
        match load_events(&base) {
            Err(AnalyzeError::Parse { line, msg }) => {
                assert_eq!(line, 1);
                assert!(msg.contains("bogus"), "{msg}");
            }
            other => panic!("expected Parse, got {other:?}"),
        }
        std::fs::remove_dir_all(&base).unwrap();
    }
}
