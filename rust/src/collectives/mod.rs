//! Communication collectives for FSSDP.
//!
//! * [`dense`] — α–β cost models of the classical collectives (AllGather,
//!   ReduceScatter, AllReduce, All-to-All, Broadcast) used by the baselines
//!   and by the paper's §3.1 comparisons.
//! * [`sparse`] — the paper's two novel collectives, `SparseAllGather`
//!   (spAG) and `SparseReduceScatter` (spRS): topology-aware transfer-plan
//!   construction and the bottleneck cost model of Equation 1.
//! * [`exec`] — executes sparse-collective plans on real host buffers across
//!   in-process simulated devices; powers the numeric FSSDP engine and the
//!   equivalence tests against dense AllReduce.

pub mod dense;
pub mod exec;
pub mod sparse;

pub use sparse::{SparsePlan, Transfer};
