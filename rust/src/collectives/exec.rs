//! Real-buffer execution of sparse collective plans.
//!
//! The numeric FSSDP engine runs N simulated devices inside one process,
//! each owning a [`ChunkStore`] of host `f32` buffers (one buffer per
//! expert). [`run_spag`] and [`run_sprs`] apply a compiled [`SparsePlan`]
//! to those stores, byte-for-byte the traffic the plan describes — this is
//! what the equivalence tests (sparse ≡ dense AllReduce on replicas) and
//! the end-to-end FSSDP training numerics run on.

use std::collections::BTreeMap;
use std::time::Instant;

use crate::placement::{ChunkId, Placement};
use crate::telemetry::{Phase as TracePhase, TraceRecorder};
use crate::topology::DeviceId;

use super::sparse::SparsePlan;

/// A free-list of `Vec<f32>` buffers: the allocation-reuse backbone of the
/// hot path. Gradient accumulators, spAG/spRS staging copies, and released
/// replica buffers all cycle through one pool, so a steady-state training
/// iteration performs no fresh chunk-buffer allocations (buffers share one
/// length per engine, so any recycled buffer fits any request).
///
/// `allocated`/`reused` are the workspace regression counters: after
/// warmup, `allocated` must stay flat across iterations (locked by
/// `fssdp::tests::workspace_allocations_stay_flat_across_a_span`).
#[derive(Debug, Clone, Default)]
pub struct BufferPool {
    free: Vec<Vec<f32>>,
    /// Fresh heap allocations served (free list was empty).
    pub allocated: u64,
    /// Requests served from the free list.
    pub reused: u64,
}

impl BufferPool {
    pub fn new() -> BufferPool {
        BufferPool::default()
    }

    /// A zeroed buffer of `len` floats, recycled when possible.
    pub fn take_zeroed(&mut self, len: usize) -> Vec<f32> {
        match self.free.pop() {
            Some(mut b) => {
                self.reused += 1;
                b.clear();
                b.resize(len, 0.0);
                b
            }
            None => {
                self.allocated += 1;
                vec![0.0; len]
            }
        }
    }

    /// A copy of `src`, recycled when possible (no intermediate zeroing).
    pub fn take_copy(&mut self, src: &[f32]) -> Vec<f32> {
        match self.free.pop() {
            Some(mut b) => {
                self.reused += 1;
                b.clear();
                b.extend_from_slice(src);
                b
            }
            None => {
                self.allocated += 1;
                src.to_vec()
            }
        }
    }

    /// Return a buffer to the free list.
    pub fn put(&mut self, mut b: Vec<f32>) {
        b.clear();
        self.free.push(b);
    }

    /// Buffers currently idle on the free list.
    pub fn idle(&self) -> usize {
        self.free.len()
    }

    /// Bytes of capacity held idle on the free list ([`BufferPool::put`]
    /// clears returned buffers, so lengths are 0 — the held memory is the
    /// capacity).
    pub fn idle_bytes(&self) -> u64 {
        self.free.iter().map(|b| b.capacity() as u64 * 4).sum()
    }
}

/// Per-device chunk buffers.
#[derive(Debug, Clone, Default)]
pub struct ChunkStore {
    bufs: BTreeMap<ChunkId, Vec<f32>>,
}

impl ChunkStore {
    pub fn new() -> ChunkStore {
        ChunkStore::default()
    }

    pub fn insert(&mut self, c: ChunkId, data: Vec<f32>) {
        self.bufs.insert(c, data);
    }

    /// Borrow a chunk's buffer. Returns a slice, not the owning `Vec` —
    /// chunk buffers never resize in place, and slices keep callers from
    /// depending on the container type.
    pub fn get(&self, c: ChunkId) -> Option<&[f32]> {
        self.bufs.get(&c).map(|b| b.as_slice())
    }

    /// Mutably borrow a chunk's buffer (fixed length — accumulate/update
    /// in place; replace wholesale via [`ChunkStore::insert`]).
    pub fn get_mut(&mut self, c: ChunkId) -> Option<&mut [f32]> {
        self.bufs.get_mut(&c).map(|b| b.as_mut_slice())
    }

    pub fn remove(&mut self, c: ChunkId) -> Option<Vec<f32>> {
        self.bufs.remove(&c)
    }

    pub fn contains(&self, c: ChunkId) -> bool {
        self.bufs.contains_key(&c)
    }

    pub fn chunks(&self) -> impl Iterator<Item = ChunkId> + '_ {
        self.bufs.keys().copied()
    }

    /// Remove every chunk for which `keep` returns false, recycling the
    /// removed buffers through `pool` — the allocation-free form of the
    /// collect-then-remove release loops.
    pub fn retain_chunks(&mut self, mut keep: impl FnMut(ChunkId) -> bool, pool: &mut BufferPool) {
        self.bufs.retain(|&c, buf| {
            if keep(c) {
                true
            } else {
                pool.put(std::mem::take(buf));
                false
            }
        });
    }

    /// Total floats resident (for memory accounting).
    pub fn resident_len(&self) -> usize {
        self.bufs.values().map(|b| b.len()).sum()
    }
}

/// The cluster's device memories for one logical buffer (e.g. one MoE
/// layer's expert parameters, or their gradients).
#[derive(Debug, Clone, Default)]
pub struct ClusterMem {
    pub devices: Vec<ChunkStore>,
}

impl ClusterMem {
    pub fn new(num_devices: usize) -> ClusterMem {
        ClusterMem { devices: vec![ChunkStore::new(); num_devices] }
    }

    pub fn dev(&self, d: DeviceId) -> &ChunkStore {
        &self.devices[d.0]
    }

    pub fn dev_mut(&mut self, d: DeviceId) -> &mut ChunkStore {
        &mut self.devices[d.0]
    }

    /// The placement implied by which buffers are resident.
    pub fn placement(&self, num_chunks: usize) -> Placement {
        let mut p = Placement::empty(num_chunks, self.devices.len());
        for (d, store) in self.devices.iter().enumerate() {
            for c in store.chunks() {
                p.add(c, DeviceId(d));
            }
        }
        p
    }

    /// Bytes resident across all devices (f32 buffers).
    pub fn total_bytes(&self) -> usize {
        self.devices.iter().map(|s| s.resident_len() * 4).sum()
    }
}

/// Execute a SparseAllGather plan: copy chunk buffers along the staged
/// transfers. Errors if a source buffer is missing (plan/state mismatch).
/// Staging copies draw from (and the caller's later releases refill)
/// `pool`, so a steady-state iteration allocates nothing here.
pub fn run_spag_pooled(
    mem: &mut ClusterMem,
    plan: &SparsePlan,
    pool: &mut BufferPool,
) -> anyhow::Result<()> {
    run_spag_traced(mem, plan, pool, None, 0, 0)
}

/// [`run_spag_pooled`] with the telemetry seam: when a recorder is passed,
/// the whole collective is recorded as one `spag_issue` span tagged
/// `(iter, layer)`, `detail` = chunk copies executed. `None` costs one
/// branch — nothing is allocated or timed into the recorder.
pub fn run_spag_traced(
    mem: &mut ClusterMem,
    plan: &SparsePlan,
    pool: &mut BufferPool,
    tracer: Option<&mut TraceRecorder>,
    iter: usize,
    layer: usize,
) -> anyhow::Result<()> {
    let t0 = Instant::now();
    let mut copies = 0u64;
    let mut payloads: Vec<(ChunkId, DeviceId, Vec<f32>)> = Vec::new();
    for stage in 0..plan.num_stages {
        // Collect the payloads first so intra-stage transfers all read the
        // pre-stage state (stages are the dependency barrier).
        payloads.clear();
        for t in plan.transfers.iter().filter(|t| t.stage == stage) {
            anyhow::ensure!(!t.reduce, "spAG plan must not contain reduce transfers");
            let src = mem.dev(t.src).get(t.chunk).ok_or_else(|| {
                anyhow::anyhow!("spAG: device {} lacks chunk {}", t.src.0, t.chunk)
            })?;
            payloads.push((t.chunk, t.dst, pool.take_copy(src)));
        }
        copies += payloads.len() as u64;
        for (chunk, dst, buf) in payloads.drain(..) {
            mem.dev_mut(dst).insert(chunk, buf);
        }
    }
    if let Some(tr) = tracer {
        tr.span_from(TracePhase::SpagIssue, iter, layer, t0, copies);
    }
    Ok(())
}

/// [`run_spag_pooled`] with a throwaway pool (cold paths and tests).
pub fn run_spag(mem: &mut ClusterMem, plan: &SparsePlan) -> anyhow::Result<()> {
    run_spag_pooled(mem, plan, &mut BufferPool::new())
}

/// Execute a SparseReduceScatter plan: accumulate gradient buffers along the
/// staged transfers, then drop non-owner replicas (the "scatter").
///
/// `owners` is the post-condition placement; after the call only owner
/// devices retain each chunk, holding the sum of all replicas. Staging
/// copies, consumed reduce payloads, and scattered replica buffers all
/// cycle through `pool`.
pub fn run_sprs_pooled(
    mem: &mut ClusterMem,
    plan: &SparsePlan,
    owners: &Placement,
    pool: &mut BufferPool,
) -> anyhow::Result<()> {
    run_sprs_traced(mem, plan, owners, pool, None, 0, 0)
}

/// [`run_sprs_pooled`] with the telemetry seam: when a recorder is passed,
/// the whole collective is recorded as one `sprs_issue` span tagged
/// `(iter, layer)`, `detail` = transfers executed (copies + reduces).
pub fn run_sprs_traced(
    mem: &mut ClusterMem,
    plan: &SparsePlan,
    owners: &Placement,
    pool: &mut BufferPool,
    tracer: Option<&mut TraceRecorder>,
    iter: usize,
    layer: usize,
) -> anyhow::Result<()> {
    let t0 = Instant::now();
    let mut moved = 0u64;
    let mut payloads: Vec<(ChunkId, DeviceId, bool, Vec<f32>)> = Vec::new();
    for stage in 0..plan.num_stages {
        payloads.clear();
        for t in plan.transfers.iter().filter(|t| t.stage == stage) {
            let src = mem.dev(t.src).get(t.chunk).ok_or_else(|| {
                anyhow::anyhow!("spRS: device {} lacks chunk {}", t.src.0, t.chunk)
            })?;
            payloads.push((t.chunk, t.dst, t.reduce, pool.take_copy(src)));
        }
        moved += payloads.len() as u64;
        for (chunk, dst, reduce, buf) in payloads.drain(..) {
            let store = mem.dev_mut(dst);
            match (reduce, store.get_mut(chunk)) {
                (true, Some(acc)) => {
                    anyhow::ensure!(acc.len() == buf.len(), "chunk size mismatch");
                    for (a, b) in acc.iter_mut().zip(buf.iter()) {
                        *a += b;
                    }
                    pool.put(buf);
                }
                (true, None) => anyhow::bail!(
                    "spRS: reduce destination {} lacks chunk {}",
                    dst.0,
                    chunk
                ),
                (false, _) => store.insert(chunk, buf),
            }
        }
    }
    // Scatter: release replicas not owned per the post-condition.
    for d in 0..mem.devices.len() {
        let dev = DeviceId(d);
        mem.devices[d].retain_chunks(|c| owners.contains(c, dev), pool);
    }
    if let Some(tr) = tracer {
        tr.span_from(TracePhase::SprsIssue, iter, layer, t0, moved);
    }
    Ok(())
}

/// [`run_sprs_pooled`] with a throwaway pool (cold paths and tests).
pub fn run_sprs(
    mem: &mut ClusterMem,
    plan: &SparsePlan,
    owners: &Placement,
) -> anyhow::Result<()> {
    run_sprs_pooled(mem, plan, owners, &mut BufferPool::new())
}

/// Reference implementation: dense AllReduce of each chunk across its
/// replica group (what rearrangement systems do, §3.1 "Comparison with
/// Rearrangement"). Every replica ends with the sum.
pub fn run_dense_allreduce(mem: &mut ClusterMem, placement: &Placement) -> anyhow::Result<()> {
    for c in 0..placement.num_chunks() {
        let holders: Vec<DeviceId> = placement.holders(c).collect();
        if holders.len() <= 1 {
            continue;
        }
        let mut sum: Option<Vec<f32>> = None;
        for &h in &holders {
            let buf = mem
                .dev(h)
                .get(c)
                .ok_or_else(|| anyhow::anyhow!("allreduce: missing chunk {c} on {}", h.0))?;
            match &mut sum {
                None => sum = Some(buf.to_vec()),
                Some(s) => {
                    for (a, b) in s.iter_mut().zip(buf.iter()) {
                        *a += b;
                    }
                }
            }
        }
        let sum = sum.unwrap();
        for &h in &holders {
            mem.dev_mut(h).insert(c, sum.clone());
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collectives::sparse::{build_spag, build_sprs};
    use crate::testing::{self, assert_allclose};
    use crate::topology::Topology;
    use crate::util::rng::Rng;

    fn fill(mem: &mut ClusterMem, p: &Placement, len: usize, rng: &mut Rng) {
        for c in 0..p.num_chunks() {
            for d in p.holders(c) {
                let buf: Vec<f32> = (0..len).map(|_| rng.normal() as f32).collect();
                mem.dev_mut(d).insert(c, buf);
            }
        }
    }

    #[test]
    fn spag_materializes_identical_copies() {
        let t = Topology::cluster_a(2, 4);
        let pre = Placement::round_robin(8, 8);
        let mut post = pre.clone();
        post.add(0, DeviceId(5));
        post.add(0, DeviceId(6));
        post.add(3, DeviceId(0));
        let plan = build_spag(&t, &pre, &post).unwrap();

        let mut mem = ClusterMem::new(8);
        let mut rng = Rng::new(1);
        fill(&mut mem, &pre, 16, &mut rng);
        let owner_buf = mem.dev(DeviceId(0)).get(0).unwrap().to_vec();

        run_spag(&mut mem, &plan).unwrap();
        assert_eq!(mem.placement(8), post);
        assert_allclose(mem.dev(DeviceId(5)).get(0).unwrap(), &owner_buf, 0.0, 0.0);
        assert_allclose(mem.dev(DeviceId(6)).get(0).unwrap(), &owner_buf, 0.0, 0.0);
    }

    #[test]
    fn sprs_matches_dense_allreduce() {
        // The paper's key equivalence: spRS(P', P) leaves the owner with the
        // same sum AllReduce would give every replica.
        let t = Topology::cluster_a(2, 4);
        let owners = Placement::round_robin(8, 8);
        let mut materialized = owners.clone();
        let mut rng = Rng::new(2);
        for _ in 0..12 {
            materialized.add(rng.below(8), DeviceId(rng.below(8)));
        }
        let mut grads = ClusterMem::new(8);
        fill(&mut grads, &materialized, 32, &mut rng);
        let mut reference = grads.clone();

        let plan = build_sprs(&t, &materialized, &owners).unwrap();
        run_sprs(&mut grads, &plan, &owners).unwrap();
        run_dense_allreduce(&mut reference, &materialized).unwrap();

        for c in 0..8 {
            let owner = owners.holders(c).next().unwrap();
            let got = grads.dev(owner).get(c).unwrap();
            let want = reference.dev(owner).get(c).unwrap();
            assert_allclose(got, want, 1e-5, 1e-5);
        }
        // non-owners released
        assert_eq!(grads.placement(8), owners);
    }

    #[test]
    fn prop_spag_then_sprs_roundtrip_scales_by_replication() {
        // Materialize with spAG (copies), backprop identical grads on every
        // replica, reduce with spRS: owner grad == replication × original.
        testing::check(
            |rng: &mut Rng, size| {
                let nodes = 1 + rng.below(3);
                let dpn = 1 + rng.below(3);
                let t = Topology::cluster_a(nodes, dpn);
                let nd = t.num_devices();
                let chunks = 1 + rng.below(size.max(1) * 2);
                let pre = Placement::round_robin(chunks, nd);
                let mut post = pre.clone();
                for _ in 0..rng.below(chunks * 2 + 1) {
                    post.add(rng.below(chunks), DeviceId(rng.below(nd)));
                }
                let seed = rng.next_u64();
                (t, pre, post, seed)
            },
            |(t, pre, post, seed)| {
                let mut rng = Rng::new(*seed);
                let mut mem = ClusterMem::new(t.num_devices());
                fill(&mut mem, pre, 8, &mut rng);
                let originals: Vec<Vec<f32>> = (0..pre.num_chunks())
                    .map(|c| {
                        let d = pre.holders(c).next().unwrap();
                        mem.dev(d).get(c).unwrap().to_vec()
                    })
                    .collect();
                let ag = build_spag(t, pre, post).map_err(|e| e.to_string())?;
                run_spag(&mut mem, &ag).map_err(|e| e.to_string())?;
                let rs = build_sprs(t, post, pre).map_err(|e| e.to_string())?;
                run_sprs(&mut mem, &rs, pre).map_err(|e| e.to_string())?;
                for c in 0..pre.num_chunks() {
                    let owner = pre.holders(c).next().unwrap();
                    let got = mem.dev(owner).get(c).ok_or("owner lost chunk")?;
                    let k = post.replication(c) as f32;
                    for (g, o) in got.iter().zip(originals[c].iter()) {
                        let want = k * o;
                        if (g - want).abs() > 1e-4 * want.abs().max(1.0) {
                            return Err(format!("chunk {c}: got {g}, want {want} (k={k})"));
                        }
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn spag_missing_source_errors() {
        let t = Topology::flat(2, 1e9);
        let pre = Placement::round_robin(2, 2);
        let mut post = pre.clone();
        post.add(0, DeviceId(1));
        let plan = build_spag(&t, &pre, &post).unwrap();
        let mut mem = ClusterMem::new(2); // buffers never filled
        assert!(run_spag(&mut mem, &plan).is_err());
    }

    #[test]
    fn memory_accounting() {
        let mut mem = ClusterMem::new(2);
        mem.dev_mut(DeviceId(0)).insert(0, vec![0.0; 100]);
        mem.dev_mut(DeviceId(1)).insert(1, vec![0.0; 50]);
        assert_eq!(mem.total_bytes(), 600);
    }

    #[test]
    fn buffer_pool_recycles_and_counts() {
        let mut pool = BufferPool::new();
        let a = pool.take_zeroed(8);
        assert_eq!(a, vec![0.0; 8]);
        assert_eq!((pool.allocated, pool.reused), (1, 0));
        pool.put(a);
        assert_eq!(pool.idle(), 1);
        // put() clears the buffer, so held memory is capacity, not length
        assert!(pool.idle_bytes() >= 8 * 4, "idle bytes track capacity");
        let b = pool.take_copy(&[1.0, 2.0, 3.0]);
        assert_eq!(b, vec![1.0, 2.0, 3.0]);
        assert_eq!((pool.allocated, pool.reused), (1, 1));
        pool.put(b);
        // a recycled buffer must come back fully zeroed regardless of its
        // previous contents
        let c = pool.take_zeroed(5);
        assert_eq!(c, vec![0.0; 5]);
        assert_eq!((pool.allocated, pool.reused), (1, 2));
    }

    #[test]
    fn retain_chunks_releases_into_the_pool() {
        let mut store = ChunkStore::new();
        store.insert(0, vec![1.0; 4]);
        store.insert(1, vec![2.0; 4]);
        store.insert(2, vec![3.0; 4]);
        let mut pool = BufferPool::new();
        store.retain_chunks(|c| c == 1, &mut pool);
        assert_eq!(store.chunks().collect::<Vec<_>>(), vec![1]);
        assert_eq!(pool.idle(), 2, "released buffers land on the free list");
    }

    #[test]
    fn pooled_collectives_match_the_plain_ones() {
        // Same traffic, same sums — the pool only changes where buffers
        // come from, never what they hold.
        let t = Topology::cluster_a(2, 2);
        let owners = Placement::round_robin(8, 4);
        let mut materialized = owners.clone();
        let mut rng = Rng::new(12);
        for _ in 0..10 {
            materialized.add(rng.below(8), DeviceId(rng.below(4)));
        }
        let spag = build_spag(&t, &owners, &materialized).unwrap();
        let sprs = build_sprs(&t, &materialized, &owners).unwrap();

        let mut plain = ClusterMem::new(4);
        fill(&mut plain, &owners, 16, &mut rng);
        let mut pooled = plain.clone();
        let mut pool = BufferPool::new();
        // warm the pool with mismatched-length garbage: recycled buffers
        // must be indistinguishable from fresh ones
        pool.put(vec![9.0; 3]);
        pool.put(vec![9.0; 40]);

        run_spag(&mut plain, &spag).unwrap();
        run_sprs(&mut plain, &sprs, &owners).unwrap();
        run_spag_pooled(&mut pooled, &spag, &mut pool).unwrap();
        run_sprs_pooled(&mut pooled, &sprs, &owners, &mut pool).unwrap();

        for c in 0..8 {
            let owner = owners.holders(c).next().unwrap();
            assert_eq!(
                pooled.dev(owner).get(c).unwrap(),
                plain.dev(owner).get(c).unwrap(),
                "chunk {c} owner sum"
            );
        }
        assert_eq!(pooled.placement(8), owners);
        assert!(pool.reused > 0, "the pool must actually recycle");
    }

    #[test]
    fn traced_collectives_record_spans_and_match_untraced() {
        let t = Topology::cluster_a(2, 2);
        let owners = Placement::round_robin(8, 4);
        let mut materialized = owners.clone();
        let mut rng = Rng::new(21);
        for _ in 0..6 {
            materialized.add(rng.below(8), DeviceId(rng.below(4)));
        }
        let spag = build_spag(&t, &owners, &materialized).unwrap();
        let sprs = build_sprs(&t, &materialized, &owners).unwrap();

        let mut plain = ClusterMem::new(4);
        fill(&mut plain, &owners, 16, &mut rng);
        let mut traced = plain.clone();
        let mut pool = BufferPool::new();
        let mut tr = TraceRecorder::new(0);

        run_spag(&mut plain, &spag).unwrap();
        run_sprs(&mut plain, &sprs, &owners).unwrap();
        run_spag_traced(&mut traced, &spag, &mut pool, Some(&mut tr), 3, 1).unwrap();
        run_sprs_traced(&mut traced, &sprs, &owners, &mut pool, Some(&mut tr), 3, 1).unwrap();

        for c in 0..8 {
            let owner = owners.holders(c).next().unwrap();
            assert_eq!(
                traced.dev(owner).get(c).unwrap(),
                plain.dev(owner).get(c).unwrap(),
                "chunk {c}: tracing must not change the numbers"
            );
        }
        let ev = tr.events();
        assert_eq!(ev.len(), 2, "one span per collective");
        assert_eq!(ev[0].phase, TracePhase::SpagIssue);
        assert_eq!(ev[1].phase, TracePhase::SprsIssue);
        assert!(ev.iter().all(|e| e.iter == 3 && e.layer == 1));
        assert_eq!(ev[0].detail, spag.transfers.len() as u64);
        assert_eq!(ev[1].detail, sprs.transfers.len() as u64);
    }
}
