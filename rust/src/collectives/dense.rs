//! α–β cost models for the classical collectives.
//!
//! These drive the baseline systems in the simulator and the paper's §3.1
//! comparison: ring AllGather/ReduceScatter move `(D-1)/D · S` per device,
//! AllReduce twice that; All-to-All is bottlenecked by the most-loaded
//! device row/column of the transfer matrix, with inter-node rows sharing
//! each node's NIC.

use crate::topology::{DeviceId, Topology};

/// Time of a ring AllGather of a buffer of `bytes` total across the group
/// `devices` (each device starts with `bytes / D` and ends with all of it).
pub fn allgather_time(topo: &Topology, devices: &[DeviceId], bytes: f64) -> f64 {
    ring_time(topo, devices, bytes)
}

/// Time of a ring ReduceScatter (same volume profile as AllGather).
pub fn reducescatter_time(topo: &Topology, devices: &[DeviceId], bytes: f64) -> f64 {
    ring_time(topo, devices, bytes)
}

/// Time of a ring AllReduce (= ReduceScatter + AllGather).
pub fn allreduce_time(topo: &Topology, devices: &[DeviceId], bytes: f64) -> f64 {
    2.0 * ring_time(topo, devices, bytes)
}

/// Ring collective: `D-1` steps, each moving `bytes/D` along the slowest
/// link in the ring.
fn ring_time(topo: &Topology, devices: &[DeviceId], bytes: f64) -> f64 {
    let d = devices.len();
    if d <= 1 || bytes <= 0.0 {
        return 0.0;
    }
    // Slowest hop in the natural ring order.
    let mut worst_bw = f64::INFINITY;
    let mut worst_lat: f64 = 0.0;
    for i in 0..d {
        let a = devices[i];
        let b = devices[(i + 1) % d];
        worst_bw = worst_bw.min(topo.bw(a, b));
        worst_lat = worst_lat.max(topo.lat(a, b));
    }
    let steps = (d - 1) as f64;
    let chunk = bytes / d as f64;
    steps * (worst_lat + chunk / worst_bw)
}

/// Time of a broadcast of `bytes` from `root` to `dsts` (tree within a node,
/// one cross-node hop per destination node).
pub fn broadcast_time(topo: &Topology, root: DeviceId, dsts: &[DeviceId], bytes: f64) -> f64 {
    if dsts.is_empty() || bytes <= 0.0 {
        return 0.0;
    }
    let cross_nodes = dsts
        .iter()
        .filter(|&&d| !topo.same_node(root, d))
        .map(|&d| topo.node_of(d))
        .collect::<std::collections::BTreeSet<_>>()
        .len();
    let intra = dsts.iter().any(|&d| topo.same_node(root, d) && d != root);
    // Root serializes cross-node sends over its NIC; intra-node forwarding
    // proceeds in parallel afterwards (pipelined tree — one extra hop).
    let mut t = cross_nodes as f64 * (topo.inter_lat + bytes / topo.inter_bw);
    if intra || cross_nodes > 0 {
        t += topo.intra_lat + bytes / topo.intra_bw;
    }
    t
}

/// Time of an All-to-All described by a transfer matrix:
/// `matrix[s][d]` = bytes sent from global device `s` to `d`.
///
/// The bottleneck analysis matches §1/§5.3: each device's outbound and
/// inbound bytes are split into intra-node traffic (NVLink) and inter-node
/// traffic; inter-node bytes from all devices of a node share that node's
/// NIC. The All-to-All finishes when the slowest port finishes.
pub fn alltoall_time(topo: &Topology, matrix: &[Vec<f64>]) -> f64 {
    let n = topo.num_devices();
    assert_eq!(matrix.len(), n, "matrix rows must equal device count");
    let mut dev_intra_out = vec![0.0f64; n];
    let mut dev_intra_in = vec![0.0f64; n];
    let mut node_inter_out = vec![0.0f64; topo.nodes];
    let mut node_inter_in = vec![0.0f64; topo.nodes];

    for s in 0..n {
        assert_eq!(matrix[s].len(), n);
        for d in 0..n {
            if s == d {
                continue;
            }
            let bytes = matrix[s][d];
            if bytes <= 0.0 {
                continue;
            }
            let (sd, dd) = (DeviceId(s), DeviceId(d));
            if topo.same_node(sd, dd) {
                dev_intra_out[s] += bytes;
                dev_intra_in[d] += bytes;
            } else {
                node_inter_out[topo.node_of(sd).0] += bytes;
                node_inter_in[topo.node_of(dd).0] += bytes;
            }
        }
    }

    let intra = dev_intra_out
        .iter()
        .chain(dev_intra_in.iter())
        .cloned()
        .fold(0.0, f64::max)
        / topo.intra_bw;
    let inter = node_inter_out
        .iter()
        .chain(node_inter_in.iter())
        .cloned()
        .fold(0.0, f64::max)
        / topo.inter_bw;
    let any_inter = node_inter_out.iter().any(|&b| b > 0.0);
    let any_intra = dev_intra_out.iter().any(|&b| b > 0.0);
    let lat = if any_inter { topo.inter_lat } else { 0.0 }
        + if any_intra { topo.intra_lat } else { 0.0 };
    intra.max(inter) + lat
}

/// Build the All-to-All matrix for token dispatch: `sends[s][d]` tokens of
/// `token_bytes` each, from the dispatch plan.
pub fn tokens_to_matrix(sends: &[Vec<usize>], token_bytes: f64) -> Vec<Vec<f64>> {
    sends
        .iter()
        .map(|row| row.iter().map(|&t| t as f64 * token_bytes).collect())
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn flat4() -> (Topology, Vec<DeviceId>) {
        let t = Topology::flat(4, 1e9);
        let d: Vec<DeviceId> = t.all_devices().collect();
        (t, d)
    }

    #[test]
    fn allreduce_is_twice_reducescatter() {
        let (t, d) = flat4();
        let rs = reducescatter_time(&t, &d, 4e6);
        let ar = allreduce_time(&t, &d, 4e6);
        assert!((ar - 2.0 * rs).abs() < 1e-12);
    }

    #[test]
    fn ring_volume_matches_closed_form() {
        let (t, d) = flat4();
        // (D-1)/D * S / bw  (+ (D-1) α)
        let s = 4e6;
        let expected = 3.0 * (1e-6 + (s / 4.0) / 1e9);
        assert!((ring_time(&t, &d, s) - expected).abs() < 1e-12);
    }

    #[test]
    fn trivial_groups_are_free() {
        let (t, d) = flat4();
        assert_eq!(allreduce_time(&t, &d[..1], 1e6), 0.0);
        assert_eq!(allgather_time(&t, &d, 0.0), 0.0);
    }

    #[test]
    fn broadcast_cross_node_serializes_on_nic() {
        let t = Topology::cluster_a(4, 8);
        let root = DeviceId(0);
        // one destination per remote node
        let dsts = vec![DeviceId(8), DeviceId(16), DeviceId(24)];
        let one = broadcast_time(&t, root, &dsts[..1], 1e6);
        let three = broadcast_time(&t, root, &dsts, 1e6);
        assert!(three > 2.5 * (one - (t.intra_lat + 1e6 / t.intra_bw)));
    }

    #[test]
    fn alltoall_balanced_vs_skewed() {
        let t = Topology::cluster_a(2, 2);
        let n = t.num_devices();
        let balanced = vec![vec![1e6; n]; n];
        let mut skewed = vec![vec![0.0; n]; n];
        // everyone sends everything to device 3 (on node 1)
        for s in 0..n {
            skewed[s][3] = 3e6;
        }
        let tb = alltoall_time(&t, &balanced);
        let ts = alltoall_time(&t, &skewed);
        assert!(ts > tb, "skewed {ts} should exceed balanced {tb}");
    }

    #[test]
    fn alltoall_internode_slower_than_intranode() {
        let t = Topology::cluster_a(2, 2);
        let n = t.num_devices();
        let mut intra = vec![vec![0.0; n]; n];
        intra[0][1] = 1e7; // same node
        let mut inter = vec![vec![0.0; n]; n];
        inter[0][2] = 1e7; // cross node
        assert!(alltoall_time(&t, &inter) > alltoall_time(&t, &intra));
    }

    #[test]
    fn tokens_matrix_scaling() {
        let m = tokens_to_matrix(&[vec![0, 2], vec![1, 0]], 4.0);
        assert_eq!(m[0][1], 8.0);
        assert_eq!(m[1][0], 4.0);
    }
}
