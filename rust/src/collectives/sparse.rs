//! The paper's two sparse collectives (§3.1): **SparseAllGather** and
//! **SparseReduceScatter**.
//!
//! Both are described by a pair of chunk placements `(pre, post)` and
//! compile to a [`SparsePlan`] — a staged list of point-to-point
//! [`Transfer`]s (the prototype in the paper schedules these as grouped
//! NCCL Broadcast/Reduce calls; p2p sends are the same traffic).
//!
//! Plans are built **topology-aware and hierarchical**: a chunk crosses any
//! node boundary at most once per destination node (stage 0), then fans out
//! intra-node (stage 1). For spRS the stages run in the opposite direction:
//! intra-node partial reduction first, then one cross-node transfer per
//! contributing node, summed at the owner.
//!
//! The cost model implements the bottleneck analysis of Equation 1:
//! `Vol(spAG(P,P')) = Vol(spRS(P',P)) = O(λS)`, with per-device intra-node
//! ports and per-node NICs as the contended resources.

use std::collections::BTreeMap;

use crate::placement::{validate_spag, validate_sprs, ChunkId, Placement};
use crate::topology::{DeviceId, Topology};

/// One point-to-point chunk transfer.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Transfer {
    pub chunk: ChunkId,
    pub src: DeviceId,
    pub dst: DeviceId,
    /// Stage index; transfers in stage `k+1` may depend on stage `k`.
    pub stage: usize,
    /// For spRS: the destination *accumulates* (sums) instead of copying.
    pub reduce: bool,
}

/// A compiled sparse collective.
#[derive(Debug, Clone)]
pub struct SparsePlan {
    pub transfers: Vec<Transfer>,
    pub num_stages: usize,
    /// λ from §3.1: fraction of chunks requiring inter-device traffic.
    pub sparsity: f64,
}

impl SparsePlan {
    pub fn empty() -> SparsePlan {
        SparsePlan { transfers: Vec::new(), num_stages: 0, sparsity: 0.0 }
    }

    /// Total bytes moved (all links), given the per-chunk byte size.
    pub fn total_bytes(&self, chunk_bytes: f64) -> f64 {
        self.transfers.len() as f64 * chunk_bytes
    }

    /// Bottleneck completion time on `topo` (Equation 1 style): per stage,
    /// the slowest port (device NVLink port or node NIC) determines the
    /// stage time; stages serialize.
    pub fn time(&self, topo: &Topology, chunk_bytes: f64) -> f64 {
        let mut total = 0.0;
        for stage in 0..self.num_stages {
            let mut dev_out: BTreeMap<usize, f64> = BTreeMap::new();
            let mut dev_in: BTreeMap<usize, f64> = BTreeMap::new();
            let mut nic_out: BTreeMap<usize, f64> = BTreeMap::new();
            let mut nic_in: BTreeMap<usize, f64> = BTreeMap::new();
            let mut any_intra = false;
            let mut any_inter = false;
            for t in self.transfers.iter().filter(|t| t.stage == stage) {
                if t.src == t.dst {
                    continue;
                }
                if topo.same_node(t.src, t.dst) {
                    any_intra = true;
                    *dev_out.entry(t.src.0).or_default() += chunk_bytes;
                    *dev_in.entry(t.dst.0).or_default() += chunk_bytes;
                } else {
                    any_inter = true;
                    *nic_out.entry(topo.node_of(t.src).0).or_default() += chunk_bytes;
                    *nic_in.entry(topo.node_of(t.dst).0).or_default() += chunk_bytes;
                }
            }
            let intra = dev_out
                .values()
                .chain(dev_in.values())
                .cloned()
                .fold(0.0, f64::max)
                / topo.intra_bw;
            let inter = nic_out
                .values()
                .chain(nic_in.values())
                .cloned()
                .fold(0.0, f64::max)
                / topo.inter_bw;
            let lat = if any_inter { topo.inter_lat } else { 0.0 }
                + if any_intra { topo.intra_lat } else { 0.0 };
            total += intra.max(inter) + lat;
        }
        total
    }
}

/// Compile `spAG(pre, post)`: materialize every `(chunk, device)` in
/// `post \ pre`, sourcing each chunk topology-aware:
///
/// 1. **stage 0** — for every destination *node* lacking the chunk, one
///    transfer from the nearest holder (same-node holder impossible by
///    construction, so a cross-node send from the owner node; among holders
///    prefer one on the least-used NIC so far);
/// 2. **stage 1** — intra-node fan-out from the node's (new or existing)
///    holder to the remaining destination devices.
pub fn build_spag(
    topo: &Topology,
    pre: &Placement,
    post: &Placement,
) -> anyhow::Result<SparsePlan> {
    validate_spag(pre, post)?;
    let mut transfers = Vec::new();
    let mut nic_out_load: BTreeMap<usize, usize> = BTreeMap::new();
    let missing = post.diff(pre);
    let mut by_chunk: BTreeMap<ChunkId, Vec<DeviceId>> = BTreeMap::new();
    for (c, d) in missing {
        by_chunk.entry(c).or_default().push(d);
    }
    let mut num_stages = 0;
    for (&chunk, dsts) in &by_chunk {
        // Group destinations by node.
        let mut by_node: BTreeMap<usize, Vec<DeviceId>> = BTreeMap::new();
        for &d in dsts {
            by_node.entry(topo.node_of(d).0).or_default().push(d);
        }
        for (&node, node_dsts) in &by_node {
            // Does any device on this node already hold the chunk (in pre)?
            let local_holder = pre
                .holders(chunk)
                .find(|&h| topo.node_of(h).0 == node);
            let fan_root = if let Some(h) = local_holder {
                h
            } else {
                // Cross-node stage-0 transfer from the least-loaded holder NIC.
                let src = pre
                    .holders(chunk)
                    .min_by_key(|h| {
                        (nic_out_load.get(&topo.node_of(*h).0).copied().unwrap_or(0), h.0)
                    })
                    .expect("pre is surjective");
                let dst = node_dsts[0];
                *nic_out_load.entry(topo.node_of(src).0).or_default() += 1;
                transfers.push(Transfer { chunk, src, dst, stage: 0, reduce: false });
                num_stages = num_stages.max(1);
                dst
            };
            // Intra-node fan-out.
            for &d in node_dsts {
                if d != fan_root {
                    transfers.push(Transfer {
                        chunk,
                        src: fan_root,
                        dst: d,
                        stage: 1,
                        reduce: false,
                    });
                    num_stages = num_stages.max(2);
                }
            }
        }
    }
    let sparsity = post.sparsity(pre);
    Ok(SparsePlan { transfers, num_stages, sparsity })
}

/// Compile `spRS(pre, post)`: reduce the gradients of every replica in
/// `pre` down to the owners in `post` (which must be a surjective subset).
///
/// 1. **stage 0** — on every node with >1 replica of a chunk, partial-reduce
///    to one node leader (the owner itself if local, else the lowest id);
/// 2. **stage 1** — each node leader sends its partial sum to the owner,
///    which accumulates.
pub fn build_sprs(
    topo: &Topology,
    pre: &Placement,
    post: &Placement,
) -> anyhow::Result<SparsePlan> {
    validate_sprs(pre, post)?;
    let mut transfers = Vec::new();
    let mut num_stages = 0;
    for chunk in 0..pre.num_chunks() {
        // Owner = the post holder (post is surjective; if multiple, each
        // owner must end with the full sum — handled by sending to each).
        let owners: Vec<DeviceId> = post.holders(chunk).collect();
        let replicas: Vec<DeviceId> = pre.holders(chunk).collect();
        if replicas.len() <= 1 {
            continue; // gradient already at its only holder (== owner)
        }
        let owner = owners[0];
        // Group replicas by node; elect leaders.
        let mut by_node: BTreeMap<usize, Vec<DeviceId>> = BTreeMap::new();
        for &d in &replicas {
            by_node.entry(topo.node_of(d).0).or_default().push(d);
        }
        let owner_node = topo.node_of(owner).0;
        for (&node, members) in &by_node {
            let leader = if node == owner_node {
                owner
            } else {
                *members.iter().min_by_key(|d| d.0).unwrap()
            };
            // stage 0: intra-node partial reduction into the leader
            for &d in members {
                if d != leader {
                    transfers.push(Transfer {
                        chunk,
                        src: d,
                        dst: leader,
                        stage: 0,
                        reduce: true,
                    });
                    num_stages = num_stages.max(1);
                }
            }
            // stage 1: cross-node partial sum to the owner
            if node != owner_node {
                transfers.push(Transfer {
                    chunk,
                    src: leader,
                    dst: owner,
                    stage: 1,
                    reduce: true,
                });
                num_stages = num_stages.max(2);
            }
        }
        // Additional owners (rare: post with replicated ownership) receive a
        // copy of the final sum in a trailing stage.
        for &extra in owners.iter().skip(1) {
            transfers.push(Transfer { chunk, src: owner, dst: extra, stage: 2, reduce: false });
            num_stages = num_stages.max(3);
        }
    }
    let sparsity = pre.sparsity(post);
    Ok(SparsePlan { transfers, num_stages, sparsity })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing;
    use crate::util::rng::Rng;

    fn topo() -> Topology {
        Topology::cluster_a(2, 4)
    }

    #[test]
    fn spag_empty_when_post_equals_pre() {
        let t = topo();
        let pre = Placement::round_robin(16, 8);
        let plan = build_spag(&t, &pre, &pre).unwrap();
        assert!(plan.transfers.is_empty());
        assert_eq!(plan.sparsity, 0.0);
        assert_eq!(plan.time(&t, 1e6), 0.0);
    }

    #[test]
    fn spag_crosses_node_once_per_dest_node() {
        let t = topo(); // 2 nodes × 4 devices
        let mut pre = Placement::empty(1, 8);
        pre.add(0, DeviceId(0)); // owner on node 0
        let mut post = pre.clone();
        // replicate to all 4 devices of node 1
        for d in 4..8 {
            post.add(0, DeviceId(d));
        }
        let plan = build_spag(&t, &pre, &post).unwrap();
        let cross: Vec<_> = plan
            .transfers
            .iter()
            .filter(|tr| !t.same_node(tr.src, tr.dst))
            .collect();
        assert_eq!(cross.len(), 1, "exactly one cross-node hop: {:?}", plan.transfers);
        assert_eq!(plan.transfers.len(), 4); // 1 cross + 3 intra fan-out
    }

    #[test]
    fn spag_prefers_local_holder() {
        let t = topo();
        let mut pre = Placement::empty(1, 8);
        pre.add(0, DeviceId(0));
        pre.add(0, DeviceId(5)); // replica already on node 1
        // pre must be surjective over chunks — it is (chunk 0 held).
        let mut post = pre.clone();
        post.add(0, DeviceId(6));
        let plan = build_spag(&t, &pre, &post).unwrap();
        assert_eq!(plan.transfers.len(), 1);
        let tr = plan.transfers[0];
        assert_eq!(tr.src, DeviceId(5), "should fan out from the node-local holder");
        assert!(t.same_node(tr.src, tr.dst));
    }

    #[test]
    fn sprs_reduces_hierarchically() {
        let t = topo();
        let mut post = Placement::empty(1, 8);
        post.add(0, DeviceId(0)); // owner on node 0
        let mut pre = post.clone();
        for d in [1, 4, 5, 6] {
            pre.add(0, DeviceId(d));
        }
        let plan = build_sprs(&t, &pre, &post).unwrap();
        // stage0: 1->0 (node0), 5->4, 6->4 (node1). stage1: 4->0.
        let cross: Vec<_> =
            plan.transfers.iter().filter(|tr| !t.same_node(tr.src, tr.dst)).collect();
        assert_eq!(cross.len(), 1, "{:?}", plan.transfers);
        assert_eq!(plan.transfers.len(), 4);
        assert!(plan.transfers.iter().all(|tr| tr.reduce));
    }

    #[test]
    fn volume_symmetry_eq1() {
        // Vol(spAG(P,P')) == Vol(spRS(P',P)) — same transfer count.
        let t = topo();
        let pre = Placement::round_robin(16, 8);
        let mut post = pre.clone();
        let mut rng = Rng::new(11);
        for _ in 0..20 {
            post.add(rng.below(16), DeviceId(rng.below(8)));
        }
        let ag = build_spag(&t, &pre, &post).unwrap();
        let rs = build_sprs(&t, &post, &pre).unwrap();
        assert_eq!(ag.total_bytes(1.0), rs.total_bytes(1.0));
        assert!((ag.sparsity - rs.sparsity).abs() < 1e-12);
    }

    #[test]
    fn sparse_cheaper_than_dense_fsdp() {
        // §3.1: O(λS) << O(S) when λ << 1.
        let t = topo();
        let chunks = 64;
        let pre = Placement::round_robin(chunks, 8);
        let mut post = pre.clone();
        post.add(0, DeviceId(3)); // materialize a single extra replica
        let plan = build_spag(&t, &pre, &post).unwrap();
        let chunk_bytes = 4e6;
        let sparse_t = plan.time(&t, chunk_bytes);
        let devices: Vec<DeviceId> = t.all_devices().collect();
        let dense_t = crate::collectives::dense::allgather_time(
            &t,
            &devices,
            chunks as f64 * chunk_bytes,
        );
        assert!(
            sparse_t < dense_t / 4.0,
            "sparse {sparse_t} should be far below dense {dense_t}"
        );
    }

    #[test]
    fn prop_spag_plan_reaches_exactly_post() {
        testing::check(
            |rng: &mut Rng, size| {
                let nodes = 1 + rng.below(3);
                let dpn = 1 + rng.below(4);
                let t = Topology::cluster_a(nodes, dpn);
                let nd = t.num_devices();
                let chunks = 1 + rng.below(4 * size.max(1));
                let pre = Placement::round_robin(chunks, nd);
                let mut post = pre.clone();
                for _ in 0..rng.below(2 * chunks + 1) {
                    post.add(rng.below(chunks), DeviceId(rng.below(nd)));
                }
                (t, pre, post)
            },
            |(t, pre, post)| {
                let plan = build_spag(t, pre, post).map_err(|e| e.to_string())?;
                // Simulate plan: devices' chunk sets start at pre, apply stages.
                let mut have = pre.clone();
                for stage in 0..plan.num_stages {
                    let mut next = have.clone();
                    for tr in plan.transfers.iter().filter(|tr| tr.stage == stage) {
                        if !have.contains(tr.chunk, tr.src) {
                            return Err(format!(
                                "stage {stage}: src {:?} lacks chunk {}",
                                tr.src, tr.chunk
                            ));
                        }
                        next.add(tr.chunk, tr.dst);
                    }
                    have = next;
                }
                if &have != post {
                    return Err("plan result != post placement".into());
                }
                Ok(())
            },
        );
    }

    #[test]
    fn prop_sprs_reduces_each_replica_once() {
        testing::check(
            |rng: &mut Rng, size| {
                let t = Topology::cluster_a(1 + rng.below(3), 1 + rng.below(4));
                let nd = t.num_devices();
                let chunks = 1 + rng.below(4 * size.max(1));
                let post = Placement::round_robin(chunks, nd);
                let mut pre = post.clone();
                for _ in 0..rng.below(2 * chunks + 1) {
                    pre.add(rng.below(chunks), DeviceId(rng.below(nd)));
                }
                (t, pre, post)
            },
            |(t, pre, post)| {
                let plan = build_sprs(t, pre, post).map_err(|e| e.to_string())?;
                // Per chunk: #reduce transfers == #replicas - 1 when single owner.
                for c in 0..pre.num_chunks() {
                    let reps = pre.replication(c);
                    let n = plan.transfers.iter().filter(|tr| tr.chunk == c).count();
                    if reps >= 1 && n != reps - 1 {
                        return Err(format!(
                            "chunk {c}: {reps} replicas but {n} transfers"
                        ));
                    }
                    // every replica is a source at most once (each partial
                    // flows exactly one way)
                    let mut src_counts: BTreeMap<usize, usize> = BTreeMap::new();
                    for tr in plan.transfers.iter().filter(|tr| tr.chunk == c) {
                        *src_counts.entry(tr.src.0).or_default() += 1;
                    }
                    if src_counts.values().any(|&v| v > 1) {
                        return Err(format!("chunk {c}: a device sends twice"));
                    }
                }
                Ok(())
            },
        );
    }
}
