//! Synthetic training data with learnable structure.
//!
//! A pure-random token stream has nothing to learn (loss would plateau at
//! ln V); instead we generate a Markov-chain corpus with a sparse
//! transition matrix, so a language model can reduce loss well below the
//! unigram entropy — giving the e2e loss curve a meaningful slope.

use crate::runtime::HostTensor;
use crate::util::rng::Rng;

/// Order-1 Markov corpus over `vocab` symbols.
pub struct SyntheticCorpus {
    vocab: usize,
    seq_len: usize,
    /// `next[tok]` — the handful of likely successors of `tok`.
    successors: Vec<Vec<usize>>,
    rng: Rng,
}

impl SyntheticCorpus {
    pub fn new(vocab: usize, seq_len: usize, seed: u64) -> SyntheticCorpus {
        let mut rng = Rng::new(seed);
        // each token has 4 likely successors (85%) + uniform noise (15%)
        let successors = (0..vocab)
            .map(|_| (0..4).map(|_| rng.below(vocab)).collect())
            .collect();
        SyntheticCorpus { vocab, seq_len, successors, rng }
    }

    /// Snapshot the stream position (checkpointing). The transition matrix
    /// is derived from the construction seed, so `(seed, rng_state)` fully
    /// determines the remaining token stream.
    pub fn rng_state(&self) -> [u64; 4] {
        self.rng.state()
    }

    /// Restore a stream position captured by [`SyntheticCorpus::rng_state`].
    /// Must be called on a corpus built with the same `(vocab, seq_len,
    /// seed)` as the one that was snapshotted.
    pub fn set_rng_state(&mut self, s: [u64; 4]) {
        self.rng = Rng::from_state(s);
    }

    fn next_token(&mut self, cur: usize) -> usize {
        if self.rng.f64() < 0.85 {
            let opts = &self.successors[cur];
            opts[self.rng.below(opts.len())]
        } else {
            self.rng.below(self.vocab)
        }
    }

    /// One `(tokens, targets)` batch: targets are inputs shifted by one.
    pub fn batch(&mut self, batch: usize) -> (HostTensor, HostTensor) {
        let mut tokens = Vec::with_capacity(batch * self.seq_len);
        let mut targets = Vec::with_capacity(batch * self.seq_len);
        for _ in 0..batch {
            let mut cur = self.rng.below(self.vocab);
            let mut seq = Vec::with_capacity(self.seq_len + 1);
            seq.push(cur);
            for _ in 0..self.seq_len {
                cur = self.next_token(cur);
                seq.push(cur);
            }
            tokens.extend(seq[..self.seq_len].iter().map(|&t| t as i32));
            targets.extend(seq[1..].iter().map(|&t| t as i32));
        }
        (
            HostTensor::i32(vec![batch, self.seq_len], tokens),
            HostTensor::i32(vec![batch, self.seq_len], targets),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn batch_shapes_and_ranges() {
        let mut c = SyntheticCorpus::new(100, 16, 1);
        let (t, y) = c.batch(3);
        assert_eq!(t.shape(), &[3, 16]);
        assert_eq!(y.shape(), &[3, 16]);
        for &v in t.as_i32().unwrap() {
            assert!((0..100).contains(&v));
        }
    }

    #[test]
    fn targets_are_shifted_inputs() {
        let mut c = SyntheticCorpus::new(50, 8, 2);
        let (t, y) = c.batch(1);
        let (t, y) = (t.as_i32().unwrap(), y.as_i32().unwrap());
        assert_eq!(&t[1..], &y[..7], "target[i] == token[i+1]");
    }

    #[test]
    fn corpus_has_structure() {
        // successor distribution concentrated: the same bigram repeats.
        let mut c = SyntheticCorpus::new(1000, 64, 3);
        let (t, y) = c.batch(64);
        let (t, y) = (t.as_i32().unwrap(), y.as_i32().unwrap());
        let mut seen = std::collections::HashMap::new();
        for (&a, &b) in t.iter().zip(y.iter()) {
            *seen.entry((a, b)).or_insert(0usize) += 1;
        }
        let repeats = seen.values().filter(|&&n| n > 1).count();
        assert!(repeats > 100, "expected repeated bigrams, got {repeats}");
    }

    #[test]
    fn deterministic() {
        let mut a = SyntheticCorpus::new(64, 8, 9);
        let mut b = SyntheticCorpus::new(64, 8, 9);
        assert_eq!(a.batch(2).0, b.batch(2).0);
    }

    #[test]
    fn rng_state_resumes_stream() {
        let mut a = SyntheticCorpus::new(64, 8, 9);
        a.batch(3); // advance
        let snap = a.rng_state();
        let expect = a.batch(2);
        let mut b = SyntheticCorpus::new(64, 8, 9);
        b.set_rng_state(snap);
        let got = b.batch(2);
        assert_eq!(got.0, expect.0);
        assert_eq!(got.1, expect.1);
    }
}
