//! End-to-end trainer: drives the AOT-compiled `*_init` / `*_train_step`
//! executables from Rust, streaming synthetic data and logging the loss
//! curve. This is the e2e validation path (EXPERIMENTS.md §E2E): all three
//! layers compose — Pallas kernels inside the JAX step inside the PJRT
//! runtime — with Python entirely off the loop.

pub mod data;

use std::io::Write as _;
use std::time::Instant;

use crate::runtime::{HostTensor, Runtime};
use crate::util::stats;

/// Result of a training run.
#[derive(Debug, Clone)]
pub struct TrainReport {
    pub steps: usize,
    pub losses: Vec<f32>,
    pub tokens_per_step: usize,
    pub mean_step_time: f64,
}

impl TrainReport {
    pub fn first_loss(&self) -> f32 {
        *self.losses.first().unwrap_or(&f32::NAN)
    }

    pub fn last_loss(&self) -> f32 {
        *self.losses.last().unwrap_or(&f32::NAN)
    }

    pub fn tokens_per_sec(&self) -> f64 {
        self.tokens_per_step as f64 / self.mean_step_time.max(1e-12)
    }
}

/// Run `steps` training steps of model `tag` ("tiny" or "e2e") from the
/// artifacts in `dir`. Logs every step's loss; optional CSV output.
pub fn run_training(
    dir: &str,
    tag: &str,
    steps: usize,
    log_csv: Option<&str>,
) -> anyhow::Result<()> {
    let report = train(dir, tag, steps, 42, |step, loss, nll, dt| {
        if step < 5 || step % 10 == 0 {
            println!("step {step:>5}  loss {loss:.4}  nll {nll:.4}  {:.0} ms", dt * 1e3);
        }
    })?;
    println!(
        "trained {} steps: loss {:.4} -> {:.4}  ({:.0} tokens/s)",
        report.steps,
        report.first_loss(),
        report.last_loss(),
        report.tokens_per_sec()
    );
    if let Some(path) = log_csv {
        let mut f = std::fs::File::create(path)?;
        writeln!(f, "step,loss")?;
        for (i, l) in report.losses.iter().enumerate() {
            writeln!(f, "{i},{l}")?;
        }
        println!("loss curve -> {path}");
    }
    anyhow::ensure!(
        report.last_loss() < report.first_loss(),
        "loss did not decrease: {} -> {}",
        report.first_loss(),
        report.last_loss()
    );
    Ok(())
}

/// Core loop, callback per step. Returns the loss curve.
pub fn train(
    dir: &str,
    tag: &str,
    steps: usize,
    seed: u64,
    mut on_step: impl FnMut(usize, f32, f32, f64),
) -> anyhow::Result<TrainReport> {
    let mut rt = Runtime::open(dir)?;
    let init_name = format!("{tag}_init");
    let step_name = format!("{tag}_train_step");

    let step_entry = rt.entry(&step_name)?.clone();
    let cfg = step_entry
        .raw
        .get("config")
        .ok_or_else(|| anyhow::anyhow!("train_step entry lacks config"))?;
    let vocab = cfg.req("vocab")?.as_usize().unwrap();
    let seq_len = cfg.req("seq_len")?.as_usize().unwrap();
    let batch = step_entry.extra_usize("batch").unwrap_or(1);
    let n_state = step_entry.inputs.len() - 2; // params+m+v+t, then tokens/targets

    crate::log_info!("initializing `{tag}` params via PJRT");
    let mut state = rt.execute(&init_name, &[HostTensor::scalar_i32(seed as i32)])?;
    anyhow::ensure!(state.len() == n_state, "init outputs {} != state {}", state.len(), n_state);

    let mut gen = data::SyntheticCorpus::new(vocab, seq_len, seed);
    let mut losses = Vec::with_capacity(steps);
    let mut times = Vec::with_capacity(steps);
    for step in 0..steps {
        let (tokens, targets) = gen.batch(batch);
        let mut inputs = state;
        inputs.push(tokens);
        inputs.push(targets);
        let t0 = Instant::now();
        let mut out = rt.execute(&step_name, &inputs)?;
        let dt = t0.elapsed().as_secs_f64();
        // outputs: loss, nll, loads, then the new state
        let loss = out[0].item_f32()?;
        let nll = out[1].item_f32()?;
        anyhow::ensure!(loss.is_finite(), "loss diverged at step {step}");
        state = out.split_off(3);
        losses.push(loss);
        times.push(dt);
        on_step(step, loss, nll, dt);
    }
    Ok(TrainReport {
        steps,
        losses,
        tokens_per_step: batch * seq_len,
        mean_step_time: stats::mean(&times),
    })
}
