//! End-to-end trainer: drives the AOT-compiled `*_init` / `*_train_step`
//! executables from Rust, streaming synthetic data and logging the loss
//! curve. This is the e2e validation path (EXPERIMENTS.md §E2E): all three
//! layers compose — Pallas kernels inside the JAX step inside the PJRT
//! runtime — with Python entirely off the loop.

pub mod data;

use std::io::Write as _;
use std::path::Path;
use std::time::Instant;

use crate::checkpoint::format::{Reader, Writer};
use crate::runtime::{HostTensor, Runtime};
use crate::util::stats;

/// Checkpoint/resume options of the e2e PJRT train loop.
#[derive(Debug, Clone, Default)]
pub struct CkptOpts {
    /// Snapshot every N steps (0 = off).
    pub every: usize,
    /// Where snapshots land (required when `every > 0`).
    pub dir: Option<String>,
    /// Resume from this checkpoint directory.
    pub resume: Option<String>,
}

impl CkptOpts {
    /// Shared cadence/destination validation — the same typed error (and
    /// exact message) the FSSDP session config produces for this
    /// misconfiguration.
    pub fn validate(&self) -> Result<(), crate::fssdp::ConfigError> {
        if self.every > 0 && self.dir.is_none() {
            return Err(crate::fssdp::ConfigError::CheckpointEveryWithoutDir);
        }
        Ok(())
    }
}

/// Result of a training run.
#[derive(Debug, Clone)]
pub struct TrainReport {
    /// Steps executed in this session (a resumed run reports only its tail).
    pub steps: usize,
    /// Global step index of `losses[0]` (0 on a fresh run).
    pub start_step: usize,
    pub losses: Vec<f32>,
    pub tokens_per_step: usize,
    pub mean_step_time: f64,
}

impl TrainReport {
    pub fn first_loss(&self) -> f32 {
        *self.losses.first().unwrap_or(&f32::NAN)
    }

    pub fn last_loss(&self) -> f32 {
        *self.losses.last().unwrap_or(&f32::NAN)
    }

    pub fn tokens_per_sec(&self) -> f64 {
        self.tokens_per_step as f64 / self.mean_step_time.max(1e-12)
    }
}

/// Run `steps` training steps of model `tag` ("tiny" or "e2e") from the
/// artifacts in `dir`. Logs every step's loss; optional CSV output.
pub fn run_training(
    dir: &str,
    tag: &str,
    steps: usize,
    log_csv: Option<&str>,
) -> anyhow::Result<()> {
    run_training_with(dir, tag, steps, log_csv, &CkptOpts::default())
}

/// [`run_training`] with checkpoint/resume flows. `steps` is the *global*
/// step target: resuming a checkpoint taken at step `k` runs `steps - k`
/// more steps.
pub fn run_training_with(
    dir: &str,
    tag: &str,
    steps: usize,
    log_csv: Option<&str>,
    ckpt: &CkptOpts,
) -> anyhow::Result<()> {
    let resumed = ckpt.resume.is_some();
    let report = train_with(dir, tag, steps, 42, ckpt, |step, loss, nll, dt| {
        if step < 5 || step % 10 == 0 {
            println!("step {step:>5}  loss {loss:.4}  nll {nll:.4}  {:.0} ms", dt * 1e3);
        }
    })?;
    println!(
        "trained {} steps: loss {:.4} -> {:.4}  ({:.0} tokens/s)",
        report.steps,
        report.first_loss(),
        report.last_loss(),
        report.tokens_per_sec()
    );
    if let Some(path) = log_csv {
        let mut f = std::fs::File::create(path)?;
        writeln!(f, "step,loss")?;
        for (i, l) in report.losses.iter().enumerate() {
            // global step ids, so a resumed tail lines up with the original
            // run's curve instead of restarting at 0
            writeln!(f, "{},{l}", report.start_step + i)?;
        }
        println!("loss curve -> {path}");
    }
    // A resumed tail can be arbitrarily short — only gate the loss trend on
    // full runs, where it is a meaningful sanity check.
    anyhow::ensure!(
        resumed || report.steps == 0 || report.last_loss() < report.first_loss(),
        "loss did not decrease: {} -> {}",
        report.first_loss(),
        report.last_loss()
    );
    Ok(())
}

/// Core loop, callback per step. Returns the loss curve.
pub fn train(
    dir: &str,
    tag: &str,
    steps: usize,
    seed: u64,
    on_step: impl FnMut(usize, f32, f32, f64),
) -> anyhow::Result<TrainReport> {
    train_with(dir, tag, steps, seed, &CkptOpts::default(), on_step)
}

/// Core loop with checkpoint/resume. The durable state is the executable's
/// state tuple (params + Adam m/v/t), the global step, the corpus seed and
/// its RNG position — saved as one `train-state.bin` blob in the same
/// version-byte-prefixed format as the FSSDP checkpoints.
pub fn train_with(
    dir: &str,
    tag: &str,
    steps: usize,
    seed: u64,
    ckpt: &CkptOpts,
    mut on_step: impl FnMut(usize, f32, f32, f64),
) -> anyhow::Result<TrainReport> {
    // Fail fast: the snapshot destination is known-required before any
    // (expensive) training step runs. One validation path with the FSSDP
    // session config, so the error message cannot drift.
    ckpt.validate()?;
    let mut rt = Runtime::open(dir)?;
    let init_name = format!("{tag}_init");
    let step_name = format!("{tag}_train_step");

    let step_entry = rt.entry(&step_name)?.clone();
    let cfg = step_entry
        .raw
        .get("config")
        .ok_or_else(|| anyhow::anyhow!("train_step entry lacks config"))?;
    let vocab = cfg.req("vocab")?.as_usize().unwrap();
    let seq_len = cfg.req("seq_len")?.as_usize().unwrap();
    let batch = step_entry.extra_usize("batch").unwrap_or(1);
    let n_state = step_entry.inputs.len() - 2; // params+m+v+t, then tokens/targets

    let (mut state, mut gen, start_step) = match &ckpt.resume {
        None => {
            crate::log_info!("initializing `{tag}` params via PJRT");
            let state = rt.execute(&init_name, &[HostTensor::scalar_i32(seed as i32)])?;
            anyhow::ensure!(
                state.len() == n_state,
                "init outputs {} != state {}",
                state.len(),
                n_state
            );
            (state, data::SyntheticCorpus::new(vocab, seq_len, seed), 0usize)
        }
        Some(rdir) => {
            let saved = load_train_state(Path::new(rdir))?;
            anyhow::ensure!(
                saved.vocab == vocab && saved.seq_len == seq_len && saved.batch == batch,
                "checkpoint was taken for vocab {} / seq {} / batch {}, artifacts say {vocab}/{seq_len}/{batch}",
                saved.vocab,
                saved.seq_len,
                saved.batch
            );
            anyhow::ensure!(
                saved.state.len() == n_state,
                "checkpoint holds {} state tensors, executable expects {n_state}",
                saved.state.len()
            );
            let mut gen = data::SyntheticCorpus::new(vocab, seq_len, saved.seed);
            gen.set_rng_state(saved.rng_state);
            crate::log_info!("resuming `{tag}` at step {} from {rdir}", saved.step);
            (saved.state, gen, saved.step)
        }
    };

    let remaining = steps.saturating_sub(start_step);
    let mut losses = Vec::with_capacity(remaining);
    let mut times = Vec::with_capacity(remaining);
    for step in start_step..steps {
        let (tokens, targets) = gen.batch(batch);
        let mut inputs = state;
        inputs.push(tokens);
        inputs.push(targets);
        let t0 = Instant::now();
        let mut out = rt.execute(&step_name, &inputs)?;
        let dt = t0.elapsed().as_secs_f64();
        // outputs: loss, nll, loads, then the new state
        let loss = out[0].item_f32()?;
        let nll = out[1].item_f32()?;
        anyhow::ensure!(loss.is_finite(), "loss diverged at step {step}");
        state = out.split_off(3);
        losses.push(loss);
        times.push(dt);
        on_step(step, loss, nll, dt);
        if ckpt.every > 0 && (step + 1) % ckpt.every == 0 {
            let cdir = ckpt.dir.as_deref().expect("validated at entry");
            let snap = TrainCkpt {
                step: step + 1,
                seed,
                vocab,
                seq_len,
                batch,
                rng_state: gen.rng_state(),
                state,
            };
            save_train_state(Path::new(cdir), &snap)?;
            state = snap.state;
        }
    }
    // A configured checkpoint dir always ends with a snapshot of the final
    // state (mirrors the fssdp flow), unless the loop just wrote one.
    if let Some(cdir) = ckpt.dir.as_deref() {
        if ckpt.every == 0 || steps % ckpt.every != 0 || remaining == 0 {
            let snap = TrainCkpt {
                // never move the step counter backwards (e.g. resuming a
                // step-100 checkpoint with --steps 50 runs nothing)
                step: steps.max(start_step),
                seed,
                vocab,
                seq_len,
                batch,
                rng_state: gen.rng_state(),
                state,
            };
            save_train_state(Path::new(cdir), &snap)?;
        }
    }
    Ok(TrainReport {
        steps: remaining,
        start_step,
        losses,
        tokens_per_step: batch * seq_len,
        mean_step_time: stats::mean(&times),
    })
}

/// Durable state of the e2e train loop.
pub struct TrainCkpt {
    pub step: usize,
    pub seed: u64,
    pub vocab: usize,
    pub seq_len: usize,
    pub batch: usize,
    pub rng_state: [u64; 4],
    pub state: Vec<HostTensor>,
}

const DTYPE_F32: u8 = 0;
const DTYPE_I32: u8 = 1;

/// Serialize the train state into `dir/train-state.bin`.
pub fn save_train_state(dir: &Path, snap: &TrainCkpt) -> anyhow::Result<()> {
    std::fs::create_dir_all(dir)?;
    let mut w = Writer::new();
    w.put_usize(snap.step);
    w.put_u64(snap.seed);
    w.put_usize(snap.vocab);
    w.put_usize(snap.seq_len);
    w.put_usize(snap.batch);
    for &s in &snap.rng_state {
        w.put_u64(s);
    }
    w.put_usize(snap.state.len());
    for t in &snap.state {
        match t {
            HostTensor::F32 { shape, data } => {
                w.put_u8(DTYPE_F32);
                w.put_usizes(shape);
                w.put_f32s(data);
            }
            HostTensor::I32 { shape, data } => {
                w.put_u8(DTYPE_I32);
                w.put_usizes(shape);
                w.put_i32s(data);
            }
        }
    }
    let bytes = w.finish();
    std::fs::write(dir.join("train-state.bin"), &bytes)?;
    crate::log_info!(
        "train checkpoint: step {} -> {} ({:.2} MB)",
        snap.step,
        dir.display(),
        bytes.len() as f64 / 1e6
    );
    Ok(())
}

/// Read a [`save_train_state`] blob from `dir`.
pub fn load_train_state(dir: &Path) -> anyhow::Result<TrainCkpt> {
    let path = dir.join("train-state.bin");
    let bytes = std::fs::read(&path)
        .map_err(|e| anyhow::anyhow!("cannot read train checkpoint {}: {e}", path.display()))?;
    let mut r = Reader::open(&bytes)?;
    let step = r.take_usize()?;
    let seed = r.take_u64()?;
    let vocab = r.take_usize()?;
    let seq_len = r.take_usize()?;
    let batch = r.take_usize()?;
    let mut rng_state = [0u64; 4];
    for s in &mut rng_state {
        *s = r.take_u64()?;
    }
    let n = r.take_usize()?;
    anyhow::ensure!(n < 1 << 20, "implausible tensor count {n}");
    let mut state = Vec::with_capacity(n);
    for i in 0..n {
        let dtype = r.take_u8()?;
        let shape = r.take_usizes()?;
        let t = match dtype {
            DTYPE_F32 => {
                let data = r.take_f32s()?;
                anyhow::ensure!(
                    shape.iter().product::<usize>() == data.len(),
                    "tensor {i}: shape {shape:?} vs {} floats",
                    data.len()
                );
                HostTensor::F32 { shape, data }
            }
            DTYPE_I32 => {
                let data = r.take_i32s()?;
                anyhow::ensure!(
                    shape.iter().product::<usize>() == data.len(),
                    "tensor {i}: shape {shape:?} vs {} ints",
                    data.len()
                );
                HostTensor::I32 { shape, data }
            }
            other => anyhow::bail!("tensor {i}: unknown dtype tag {other}"),
        };
        state.push(t);
    }
    r.done()?;
    Ok(TrainCkpt { step, seed, vocab, seq_len, batch, rng_state, state })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn train_state_blob_roundtrip() {
        let dir = std::env::temp_dir()
            .join(format!("hecate-train-ckpt-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let snap = TrainCkpt {
            step: 17,
            seed: 42,
            vocab: 1024,
            seq_len: 32,
            batch: 2,
            rng_state: [9, 8, 7, 6],
            state: vec![
                HostTensor::f32(vec![2, 3], vec![0.5, -1.5, 2.0, 0.0, -0.25, 3.5]),
                HostTensor::i32(vec![3], vec![1, -2, 3]),
                HostTensor::scalar_i32(5),
            ],
        };
        save_train_state(&dir, &snap).unwrap();
        let back = load_train_state(&dir).unwrap();
        assert_eq!(back.step, 17);
        assert_eq!(back.seed, 42);
        assert_eq!((back.vocab, back.seq_len, back.batch), (1024, 32, 2));
        assert_eq!(back.rng_state, [9, 8, 7, 6]);
        assert_eq!(back.state, snap.state);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn checkpoint_cadence_without_dir_keeps_the_cli_error_string() {
        let err = CkptOpts { every: 5, dir: None, resume: None }.validate().unwrap_err();
        assert_eq!(err.to_string(), "--checkpoint-every needs --checkpoint-dir");
        assert!(CkptOpts::default().validate().is_ok());
    }

    #[test]
    fn missing_train_state_errors_helpfully() {
        let err = load_train_state(Path::new("/nonexistent-ckpt-dir"))
            .unwrap_err()
            .to_string();
        assert!(err.contains("train checkpoint"), "{err}");
    }
}
