//! SPMD executor benchmarks: sequential vs per-rank concurrent execution
//! of the sparse collectives, and the end-to-end FSSDP step on the
//! `Executor::Sequential` vs `Executor::Spmd` seam — the acceptance bench
//! for the parallel runtime (the SPMD rows should win on a multicore
//! host; the collective-only rows mostly price the communicator, since
//! buffer copies are memory-bound).
//!
//! `cargo bench --bench spmd [-- --quick] [filter]`

use hecate::bench::Bench;
use hecate::collectives::exec::{run_spag, run_sprs, ClusterMem};
use hecate::collectives::sparse::{build_spag, build_sprs};
use hecate::fssdp::{LayerDims, Session, SessionConfig};
use hecate::placement::Placement;
use hecate::spmd::comm::{self, Pacing};
use hecate::spmd::exec::{run_spag_rank, run_sprs_rank};
use hecate::topology::{DeviceId, Topology};
use hecate::util::rng::Rng;

/// A reference-backend session on `topo`; `spmd = Some((threads, overlap))`
/// selects the parallel executor.
fn session(
    dims: LayerDims,
    layers: usize,
    topo: Topology,
    spmd: Option<(usize, bool)>,
    sources: usize,
    pacing: Option<Pacing>,
) -> Session {
    let mut b = SessionConfig::builder()
        .reference()
        .dims(dims)
        .topology(topo)
        .layers(layers)
        .seed(9)
        .data_shards(sources);
    if let Some((threads, overlap)) = spmd {
        b = b.parallel(true).threads(threads).overlap(overlap);
    }
    if let Some(p) = pacing {
        b = b.pacing(p);
    }
    Session::fresh(b.build().unwrap()).unwrap()
}

fn materialized(pre: &Placement, extra: usize, seed: u64) -> Placement {
    let mut rng = Rng::new(seed);
    let mut post = pre.clone();
    for _ in 0..extra {
        post.add(rng.below(pre.num_chunks()), DeviceId(rng.below(pre.num_devices())));
    }
    post
}

fn main() {
    let b = Bench::from_args();
    let nd = 8;
    let topo = Topology::cluster_a(2, 4);
    let pre = Placement::round_robin(32, nd);
    let post = materialized(&pre, 48, 1);
    let spag = build_spag(&topo, &pre, &post).unwrap();
    let sprs = build_sprs(&topo, &post, &pre).unwrap();

    let chunk = 16_384;
    let mut base = ClusterMem::new(nd);
    let mut rng = Rng::new(2);
    for c in 0..pre.num_chunks() {
        let d = pre.holders(c).next().unwrap();
        base.dev_mut(d).insert(c, (0..chunk).map(|_| rng.normal() as f32).collect());
    }
    let mut full = base.clone();
    run_spag(&mut full, &spag).unwrap();

    b.section("spAG execution: sequential loop vs 8 rank threads (32 chunks x 16k floats)");
    b.run("spag_sequential", || {
        let mut mem = base.clone();
        run_spag(&mut mem, &spag).unwrap();
    });
    b.run("spag_8rank_threads", || {
        let comms = comm::fabric(nd, None);
        let stores = base.devices.clone();
        std::thread::scope(|sc| {
            for (me, (mut store, mut c)) in stores.into_iter().zip(comms).enumerate() {
                let plan = &spag;
                sc.spawn(move || run_spag_rank(&mut store, plan, me, 0, 0, &mut c).unwrap());
            }
        });
    });

    b.section("spRS execution: sequential loop vs 8 rank threads");
    b.run("sprs_sequential", || {
        let mut mem = full.clone();
        run_sprs(&mut mem, &sprs, &pre).unwrap();
    });
    b.run("sprs_8rank_threads", || {
        let comms = comm::fabric(nd, None);
        let stores = full.devices.clone();
        std::thread::scope(|sc| {
            for (me, (mut store, mut c)) in stores.into_iter().zip(comms).enumerate() {
                let plan = &sprs;
                let owners = &pre;
                sc.spawn(move || {
                    run_sprs_rank(&mut store, plan, owners, me, 0, 0, &mut c).unwrap()
                });
            }
        });
    });

    b.section("end-to-end FSSDP step, 8 devices (tokens 128, d_model 64, d_ffn 128, 16 experts)");
    let dims = LayerDims { tokens: 128, d_model: 64, d_ffn: 128, experts: 16, cap: 32 };
    // Sessions track the absolute step internally, so each closure call
    // runs the next iteration of a continuing trajectory.
    let mut seq = session(dims, 1, Topology::cluster_a(2, 4), None, nd, None);
    b.run("step_sequential_8dev", || {
        seq.run(1).unwrap();
    });
    let mut par = session(dims, 1, Topology::cluster_a(2, 4), Some((nd, true)), nd, None);
    b.run("step_spmd_8threads", || {
        par.run(1).unwrap();
    });
    let mut par_sync = session(dims, 1, Topology::cluster_a(2, 4), Some((nd, false)), nd, None);
    b.run("step_spmd_8threads_no_overlap", || {
        par_sync.run(1).unwrap();
    });

    b.section(
        "cross-layer overlap (paper's §4.3 pipeline): 3-layer stack, 4 ranks, \
         α–β-paced links — overlap on should win wall clock",
    );
    let mdims = LayerDims { tokens: 32, d_model: 16, d_ffn: 32, experts: 8, cap: 16 };
    let chunk_bytes = mdims.chunk_len() as f64 * 4.0;
    // pace so one chunk transfer costs ~0.2 ms: materialization time is
    // physically on the clock, and hiding it is measurable
    let pacing = Pacing::uniform(chunk_bytes / 200e-6, 20e-6);
    for overlap in [false, true] {
        let mut s =
            session(mdims, 3, Topology::cluster_a(2, 2), Some((4, overlap)), 4, Some(pacing));
        let name = if overlap {
            "step_3layers_crosslayer_overlap_on"
        } else {
            "step_3layers_crosslayer_overlap_off"
        };
        b.run(name, || {
            s.run(1).unwrap();
        });
    }
}
