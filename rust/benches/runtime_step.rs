//! End-to-end runtime benchmarks: the hermetic reference-backend training
//! step (8 devices × 3 layers — the zero-copy hot path's acceptance
//! benchmark, in-line and threaded expert loops), then PJRT executable
//! invocation latency (the L3↔L2 boundary) and one full numeric-FSSDP
//! engine iteration. The PJRT sections are skipped gracefully when
//! `artifacts/` is absent; the reference section always runs.
//!
//! `cargo bench --bench runtime_step [-- --quick] [filter]`

use hecate::bench::Bench;
use hecate::fssdp::{ComputeMode, LayerDims, Session, SessionConfig};
use hecate::runtime::{HostTensor, Runtime};
use hecate::topology::Topology;

fn main() {
    let b = Bench::from_args();

    // ---- hermetic: the reference-backend step (no artifacts needed) ----
    b.section("reference engine step (8 devices x 3 layers, hermetic)");
    let dims = LayerDims { tokens: 64, d_model: 48, d_ffn: 96, experts: 8, cap: 32 };
    let hermetic_session = |threads: usize, mode: ComputeMode| {
        Session::fresh(
            SessionConfig::builder()
                .reference()
                .dims(dims)
                .topology(Topology::cluster_a(2, 4))
                .layers(3)
                .seed(5)
                .data_shards(8)
                .compute_threads(threads)
                .compute_mode(mode)
                .build()
                .unwrap(),
        )
        .unwrap()
    };
    let mut seq = hermetic_session(1, ComputeMode::Reference);
    seq.run(1).unwrap(); // warm the workspace and pool
    b.run("reference_step_8dev_3layer", || {
        seq.run(1).unwrap();
    });
    let mut thr = hermetic_session(4, ComputeMode::Reference);
    thr.run(1).unwrap();
    b.run("reference_step_8dev_3layer_threads4", || {
        thr.run(1).unwrap();
    });
    let mut fast = hermetic_session(1, ComputeMode::Fast);
    fast.run(1).unwrap();
    b.run("fast_step_8dev_3layer", || {
        fast.run(1).unwrap();
    });
    let mut fast_thr = hermetic_session(4, ComputeMode::Fast);
    fast_thr.run(1).unwrap();
    b.run("fast_step_8dev_3layer_threads4", || {
        fast_thr.run(1).unwrap();
    });

    if !std::path::Path::new("artifacts/manifest.json").exists() {
        eprintln!("artifacts/ missing — run `make artifacts` first; skipping PJRT sections");
        return;
    }

    b.section("PJRT executable invocation");
    let mut rt = Runtime::open("artifacts").unwrap();
    let gate = rt.entry("gate_fwd").unwrap().clone();
    let (t, dm) = (gate.inputs[0].shape[0], gate.inputs[0].shape[1]);
    let experts = gate.inputs[1].shape[1];
    let x = HostTensor::f32(vec![t, dm], vec![0.1; t * dm]);
    let wg = HostTensor::f32(vec![dm, experts], vec![0.05; dm * experts]);
    b.run_val("gate_fwd_hlo", || rt.execute("gate_fwd", &[x.clone(), wg.clone()]).unwrap());

    let ffn = rt.entry("expert_ffn_fwd").unwrap().clone();
    let (cap, dff) = (ffn.inputs[0].shape[0], ffn.inputs[1].shape[1]);
    let args = vec![
        HostTensor::f32(vec![cap, dm], vec![0.1; cap * dm]),
        HostTensor::f32(vec![dm, dff], vec![0.02; dm * dff]),
        HostTensor::f32(vec![dff], vec![0.0; dff]),
        HostTensor::f32(vec![dff, dm], vec![0.02; dff * dm]),
        HostTensor::f32(vec![dm], vec![0.0; dm]),
    ];
    b.run_val("expert_ffn_fwd_hlo", || rt.execute("expert_ffn_fwd", &args).unwrap());
    let mut bwd_args = args.clone();
    bwd_args.push(HostTensor::f32(vec![cap, dm], vec![0.01; cap * dm]));
    b.run_val("expert_ffn_bwd_hlo", || rt.execute("expert_ffn_bwd", &bwd_args).unwrap());

    b.section("numeric FSSDP engine");
    let mut engine = Session::fresh(
        SessionConfig::builder()
            .pjrt("artifacts")
            .topology(Topology::cluster_a(2, 4))
            .seed(5)
            .data_shards(8)
            .build()
            .unwrap(),
    )
    .unwrap();
    b.run("fssdp_full_iteration_8dev", || {
        engine.run(1).unwrap();
    });

    b.section("tiny train step (full model fwd+bwd+Adam)");
    let mut state = rt
        .execute("tiny_init", &[HostTensor::scalar_i32(0)])
        .unwrap();
    let step_entry = rt.entry("tiny_train_step").unwrap().clone();
    let batch = step_entry.extra_usize("batch").unwrap_or(2);
    let seq = step_entry.inputs[step_entry.inputs.len() - 2].shape[1];
    let tokens = HostTensor::i32(vec![batch, seq], vec![1; batch * seq]);
    let targets = HostTensor::i32(vec![batch, seq], vec![2; batch * seq]);
    b.run("tiny_train_step_hlo", || {
        let mut inputs = state.clone();
        inputs.push(tokens.clone());
        inputs.push(targets.clone());
        let out = rt.execute("tiny_train_step", &inputs).unwrap();
        state = out[3..].to_vec();
    });
}
