//! Benchmarks of the placement planners: Algorithm 1 (sparse
//! materialization), Algorithm 2 (heterogeneous sharding), and the load
//! predictor. These run once per iteration (Alg 1) or per re-shard
//! (Alg 2) in the coordinator; both must stay negligible next to a
//! ~100 ms training iteration.
//!
//! `cargo bench --bench planner [-- --quick] [filter]`

use hecate::bench::Bench;
use hecate::loadsim::{LoadPredictor, ModelLoadTrace};
use hecate::materialize::{sparse_materialize, MatConstraints};
use hecate::placement::Placement;
use hecate::sharding::heterogeneous;
use hecate::topology::Topology;
use hecate::util::rng::Rng;

fn main() {
    let b = Bench::from_args();
    let topo = Topology::cluster_a(4, 8);
    let mut rng = Rng::new(1);

    b.section("Algorithm 1: sparse materialization (64 experts, 32 devices)");
    let shards = Placement::round_robin(64, 32);
    let loads = rng.dirichlet(0.2, 64);
    for (t, m) in [(4, 8), (16, 4), (32, 2)] {
        b.run_val(&format!("alg1_t{t}_m{m}"), || {
            sparse_materialize(
                &topo,
                &shards,
                &loads,
                MatConstraints { overlap_degree: t, mem_slots: m },
            )
        });
    }

    b.section("Algorithm 2: heterogeneous sharding (12 layers x 64 experts)");
    let all_loads: Vec<Vec<f64>> = (0..12).map(|_| rng.dirichlet(0.2, 64)).collect();
    for t in [8usize, 16] {
        b.run_val(&format!("alg2_12x64_t{t}"), || heterogeneous(&topo, &all_loads, t));
    }
    let deep: Vec<Vec<f64>> = (0..24).map(|_| rng.dirichlet(0.2, 64)).collect();
    b.run_val("alg2_24x64_t8", || heterogeneous(&topo, &deep, 8));

    b.section("full simulator iteration (gpt-moe-s, 32 devices)");
    {
        use hecate::config::{ClusterPreset, ModelConfig, SystemConfig, SystemKind, TrainConfig};
        use hecate::sim::engine::{simulate, SimOptions};
        let topo = ClusterPreset::A.build(4, 8);
        let model = ModelConfig::preset("gpt-moe-s").unwrap();
        let train = TrainConfig { batch_per_device: 4, ..Default::default() };
        let opts = SimOptions { iterations: 10, warmup: 2, seed: 3, balanced_loads: false };
        for kind in [SystemKind::Ep, SystemKind::Hecate, SystemKind::FlexMoe] {
            b.run_val(&format!("simulate_10it_{}", kind.name()), || {
                simulate(&topo, &model, &SystemConfig::new(kind), &train, &opts)
            });
        }
    }

    b.section("load prediction");
    let mut predictor = LoadPredictor::new(64, 5);
    let mut trace = ModelLoadTrace::new(1, 64, 3);
    for _ in 0..5 {
        predictor.observe(&trace.step()[0]);
    }
    b.run_val("predictor_predict_64", || predictor.predict());
    b.run_val("loadgen_step_64", || trace.step());
}
