//! Microbenchmarks of the sparse collectives: plan construction, cost
//! evaluation, and real-buffer execution — the L3 hot path of every FSSDP
//! iteration (perf pass target: plan+exec well under the per-layer budget).
//!
//! `cargo bench --bench collectives [-- --quick] [filter]`

use hecate::bench::Bench;
use hecate::collectives::exec::{run_spag, run_sprs, ClusterMem};
use hecate::collectives::sparse::{build_spag, build_sprs};
use hecate::placement::Placement;
use hecate::topology::{DeviceId, Topology};
use hecate::util::rng::Rng;

fn materialized(pre: &Placement, extra: usize, seed: u64) -> Placement {
    let mut rng = Rng::new(seed);
    let mut post = pre.clone();
    for _ in 0..extra {
        post.add(rng.below(pre.num_chunks()), DeviceId(rng.below(pre.num_devices())));
    }
    post
}

fn main() {
    let b = Bench::from_args();
    b.section("sparse collective planning (64 experts, 32 devices)");
    let topo = Topology::cluster_a(4, 8);
    let pre = Placement::round_robin(64, 32);
    let post = materialized(&pre, 96, 1);

    b.run_val("spag_plan_build", || build_spag(&topo, &pre, &post).unwrap());
    b.run_val("sprs_plan_build", || build_sprs(&topo, &post, &pre).unwrap());

    let spag = build_spag(&topo, &pre, &post).unwrap();
    b.run_val("spag_cost_eval", || spag.time(&topo, 4.7e6));

    b.section("real-buffer execution (chunk = 16k floats)");
    let chunk = 16_576;
    let mut base = ClusterMem::new(32);
    let mut rng = Rng::new(2);
    for c in 0..64 {
        let d = pre.holders(c).next().unwrap();
        base.dev_mut(d).insert(c, (0..chunk).map(|_| rng.normal() as f32).collect());
    }
    b.run("spag_exec_64x16k", || {
        let mut mem = base.clone();
        run_spag(&mut mem, &spag).unwrap();
    });

    let sprs = build_sprs(&topo, &post, &pre).unwrap();
    let mut full = base.clone();
    run_spag(&mut full, &spag).unwrap();
    b.run("sprs_exec_64x16k", || {
        let mut mem = full.clone();
        run_sprs(&mut mem, &sprs, &pre).unwrap();
    });

    b.section("dense cost models");
    let devices: Vec<DeviceId> = topo.all_devices().collect();
    b.run_val("allreduce_cost", || {
        hecate::collectives::dense::allreduce_time(&topo, &devices, 1e8)
    });
    let matrix = vec![vec![1e5; 32]; 32];
    b.run_val("alltoall_cost_32x32", || {
        hecate::collectives::dense::alltoall_time(&topo, &matrix)
    });
}
