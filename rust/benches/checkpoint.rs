//! Benchmarks of the checkpoint subsystem: shard blob serialize /
//! deserialize throughput vs shard count, full save→load through the
//! filesystem, and the elastic reshard planner.
//!
//! `cargo bench --bench checkpoint [-- --quick] [filter]`

use hecate::bench::Bench;
use hecate::checkpoint::{self, format, reshard, shard, ExpertState, LayerCkpt, TrainState};
use hecate::fssdp::{LayerDims, Session, SessionConfig};
use hecate::topology::Topology;
use hecate::util::rng::Rng;

/// Build a synthetic v2 TrainState: `layers` layers of `experts` shards of
/// `chunk_len` floats each.
fn state_layers(
    experts: usize,
    d_model: usize,
    d_ffn: usize,
    world: usize,
    layers: usize,
) -> TrainState {
    let dims = LayerDims { tokens: 64, d_model, d_ffn, experts, cap: 64 };
    let cl = dims.chunk_len();
    let mut rng = Rng::new(1);
    let mut rng2 = Rng::new(2);
    let layers_v: Vec<LayerCkpt> = (0..layers)
        .map(|l| {
            let mut mk = || -> Vec<f32> { (0..cl).map(|_| rng.normal() as f32).collect() };
            LayerCkpt {
                owners: (0..experts).map(|e| (e + l) % world).collect(),
                experts: (0..experts)
                    .map(|_| ExpertState { chunk: mk(), m: mk(), v: mk(), t: 5 })
                    .collect(),
                gate_w: (0..d_model * experts).map(|_| rng2.normal() as f32).collect(),
                predictor_history: (0..5).map(|_| rng2.dirichlet(0.3, experts)).collect(),
            }
        })
        .collect();
    TrainState {
        step: 100,
        dims,
        seed: 1,
        data_shards: world,
        layers: layers_v,
        predictor_window: 5,
        rng_state: [1, 2, 3, 4],
        mem_slots: 4,
        overlap_degree: 4,
        reshard_every: 0,
    }
}

fn state(experts: usize, d_model: usize, d_ffn: usize, world: usize) -> TrainState {
    state_layers(experts, d_model, d_ffn, world, 1)
}

fn mb(bytes: usize) -> f64 {
    bytes as f64 / 1e6
}

fn main() {
    let b = Bench::from_args();

    b.section("shard blob serialize/deserialize vs shard count");
    for (experts, d_model) in [(8usize, 32usize), (32, 64), (64, 128)] {
        let world = 8;
        let st = state(experts, d_model, 2 * d_model, world);
        let ids: Vec<Vec<usize>> =
            vec![(0..experts).filter(|e| e % world == 0).collect()];
        let blob = shard::encode_rank(&st, 0, &ids);
        println!(
            "  [e{experts} d{d_model}] rank blob {:.2} MB ({} experts/rank)",
            mb(blob.len()),
            ids[0].len()
        );
        b.run_val(&format!("encode_rank_e{experts}_d{d_model}"), || {
            shard::encode_rank(&st, 0, &ids)
        });
        b.run_val(&format!("decode_rank_e{experts}_d{d_model}"), || {
            shard::decode_rank(&blob, st.dims.chunk_len(), 1).unwrap()
        });
        b.run_val(&format!("fnv1a64_e{experts}_d{d_model}"), || format::fnv1a64(&blob));
    }

    b.section("global blob");
    let st = state(64, 64, 128, 8);
    let blob = shard::encode_global(&st);
    println!("  global blob {:.3} MB", mb(blob.len()));
    b.run_val("encode_global_e64", || shard::encode_global(&st));
    b.run_val("decode_global_e64", || shard::decode_global(&blob).unwrap());

    b.section("multi-layer (v2) blobs: 12 layers x 64 experts");
    let st12 = state_layers(64, 64, 128, 8, 12);
    let ids12: Vec<Vec<usize>> =
        (0..12).map(|l| (0..64usize).filter(|e| (e + l) % 8 == 0).collect()).collect();
    let blob12 = shard::encode_rank(&st12, 0, &ids12);
    println!("  12-layer rank blob {:.2} MB", mb(blob12.len()));
    b.run_val("encode_rank_12layers", || shard::encode_rank(&st12, 0, &ids12));
    b.run_val("decode_rank_12layers", || {
        shard::decode_rank(&blob12, st12.dims.chunk_len(), 12).unwrap()
    });

    b.section("full checkpoint save+load through the filesystem");
    let dir = std::env::temp_dir().join(format!("hecate-bench-ckpt-{}", std::process::id()));
    let topo = Topology::cluster_a(2, 4);
    for experts in [16usize, 64] {
        let st = state(experts, 64, 128, topo.num_devices());
        b.run_val(&format!("save_e{experts}_w8"), || {
            checkpoint::save(&dir, &st, &topo).unwrap()
        });
        checkpoint::save(&dir, &st, &topo).unwrap();
        b.run_val(&format!("load_e{experts}_w8"), || checkpoint::load(&dir).unwrap());
    }
    let _ = std::fs::remove_dir_all(&dir);

    b.section("elastic reshard planning (64 experts)");
    let st = state(64, 64, 128, 8);
    for (nodes, dpn, tag) in [(1usize, 4usize, "shrink_8to4"), (4, 8, "grow_8to32")] {
        let target = Topology::cluster_a(nodes, dpn);
        b.run_val(&format!("reshard_plan_{tag}"), || {
            reshard::plan(&st, 8, &target).unwrap()
        });
    }

    b.section("end-to-end Session checkpoint/resume (reference engine, 2 layers)");
    let sdir = std::env::temp_dir().join(format!("hecate-bench-session-{}", std::process::id()));
    let cfg = || {
        SessionConfig::builder()
            .reference()
            .topology(Topology::cluster_a(2, 2))
            .layers(2)
            .seed(5)
            .build()
            .unwrap()
    };
    let mut trained = Session::fresh(cfg()).unwrap();
    trained.run(2).unwrap();
    b.run_val("session_checkpoint_to", || trained.checkpoint_to(&sdir).unwrap());
    trained.checkpoint_to(&sdir).unwrap();
    b.run_val("session_resume_same_world", || Session::resume(cfg(), &sdir).unwrap());
    let _ = std::fs::remove_dir_all(&sdir);
}
