//! One bench target per paper table/figure: times the full simulation that
//! regenerates each artifact AND prints the resulting rows (so `cargo
//! bench --bench figures` doubles as the repro driver with timing).
//!
//! `cargo bench --bench figures [-- --quick] [fig09|fig10|fig11|fig12|fig13|fig14|fig15|claims|table1]`

use hecate::bench::Bench;
use hecate::config::ClusterPreset;
use hecate::sim::engine::SimOptions;
use hecate::sim::report;

fn main() {
    let mut b = Bench::from_args();
    // each figure is a multi-second simulation sweep: keep sample counts
    // small so `cargo bench` stays minutes, not hours, on small hosts.
    b.samples = b.samples.min(3);
    b.warmup = b.warmup.min(1);
    b.min_sample_time = std::time::Duration::ZERO;
    let opts = SimOptions { iterations: 30, warmup: 6, seed: 42, balanced_loads: false };

    if let Some(r) = b.run_val("table1", report::table1) {
        let _ = r;
        print!("{}", report::table1().to_markdown());
    }
    if b.run_val("fig03_load_trace", || report::figure3(30)).is_some() {
        // rows printed on demand via `hecate repro --figure 3`
    }
    if b.run_val("fig09_cluster_a_32gpu", || {
        report::end_to_end(ClusterPreset::A, 4, 8, &opts)
    })
    .is_some()
    {
        print!("{}", report::end_to_end(ClusterPreset::A, 4, 8, &opts).to_markdown());
    }
    if b.run_val("fig10_cluster_b_32gpu", || report::figure10(&opts)).is_some() {
        print!("{}", report::figure10(&opts).to_markdown());
    }
    if b.run_val("fig11_layerwise", || report::figure11(&opts)).is_some() {
        print!("{}", report::figure11(&opts).to_markdown());
    }
    if b.run_val("fig12_breakdown", || report::figure12(&opts)).is_some() {
        print!("{}", report::figure12(&opts).to_markdown());
    }
    if b.run_val("fig13_memory", || report::figure13(&opts)).is_some() {
        print!("{}", report::figure13(&opts).to_markdown());
    }
    if b.run_val("fig14_batch_scaling", || report::figure14(&opts)).is_some() {
        print!("{}", report::figure14(&opts).to_markdown());
    }
    if b.run_val("fig15a_ablation", || report::figure15a(&opts)).is_some() {
        print!("{}", report::figure15a(&opts).to_markdown());
    }
    if b.run_val("fig15b_reshard_interval", || report::figure15b(&opts)).is_some() {
        print!("{}", report::figure15b(&opts).to_markdown());
    }
    if b.run_val("claims_section1", || report::claims(&opts)).is_some() {
        for (name, t) in report::claims(&opts) {
            println!("-- {name} --");
            print!("{}", t.to_markdown());
        }
    }
}
