//! Benchmarks of the topology-aware token dispatcher (§4.4): the per-layer
//! per-iteration routing decision in the coordinator hot path.
//!
//! `cargo bench --bench dispatch [-- --quick] [filter]`

use hecate::bench::Bench;
use hecate::dispatch::dispatch;
use hecate::placement::Placement;
use hecate::topology::{DeviceId, Topology};
use hecate::util::rng::Rng;

fn main() {
    let b = Bench::from_args();
    let topo = Topology::cluster_a(4, 8);
    let mut rng = Rng::new(1);

    for (experts, tokens) in [(32usize, 4096usize), (64, 8192), (64, 16384)] {
        let mut placement = Placement::round_robin(experts, 32);
        for _ in 0..experts {
            placement.add(rng.below(experts), DeviceId(rng.below(32)));
        }
        let f = rng.dirichlet(0.3, experts);
        let asg: Vec<Vec<usize>> = (0..32)
            .map(|_| f.iter().map(|p| (p * tokens as f64) as usize).collect())
            .collect();
        b.run_val(&format!("dispatch_e{experts}_t{tokens}"), || {
            dispatch(&topo, &placement, &asg)
        });
    }

    // fully-replicated worst case (most candidates per token)
    let placement = Placement::full(64, 32);
    let f = rng.dirichlet(0.3, 64);
    let asg: Vec<Vec<usize>> =
        (0..32).map(|_| f.iter().map(|p| (p * 8192.0) as usize).collect()).collect();
    b.run_val("dispatch_full_replication", || dispatch(&topo, &placement, &asg));
}
