//! API-compatible stub of the `xla-rs` bindings used by `hecate::runtime`.
//!
//! The offline build has no libpjrt / XLA shared library to link against, so
//! this crate provides the small API surface the runtime layer uses:
//!
//! * [`Literal`] is **fully functional** — it is a plain host tensor
//!   (f32/i32/tuple) and round-trips through `vec1`/`reshape`/`to_vec`, so
//!   the `HostTensor` ↔ `Literal` conversion layer and its unit tests work
//!   unchanged.
//! * [`PjRtClient::cpu`] **reports unavailability** — paths that would
//!   execute compiled HLO (the PJRT train loop, the artifact-gated
//!   integration tests) error out with a clear message or self-skip, exactly
//!   as they do on a machine without `artifacts/`.
//!
//! Swapping in the real `xla-rs` crate restores full functionality without
//! any source change in `hecate`.

use std::fmt;

/// Stub error type: everything is a message.
#[derive(Debug, Clone)]
pub struct Error {
    msg: String,
}

impl Error {
    pub fn new(msg: impl Into<String>) -> Error {
        Error { msg: msg.into() }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "xla: {}", self.msg)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

const UNAVAILABLE: &str = "PJRT runtime unavailable: hecate was built against the bundled \
     `xla` API stub (offline build, no libpjrt). Numeric paths that execute compiled HLO are \
     disabled; artifact-gated tests self-skip.";

/// Element types of array literals (subset of XLA's PrimitiveType).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    Pred,
    S8,
    S16,
    S32,
    S64,
    U8,
    U16,
    U32,
    U64,
    F16,
    Bf16,
    F32,
    F64,
    C64,
}

/// Typed element storage of an array literal.
#[derive(Debug, Clone, PartialEq)]
pub enum LiteralData {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

impl LiteralData {
    fn len(&self) -> usize {
        match self {
            LiteralData::F32(v) => v.len(),
            LiteralData::I32(v) => v.len(),
        }
    }

    fn ty(&self) -> ElementType {
        match self {
            LiteralData::F32(_) => ElementType::F32,
            LiteralData::I32(_) => ElementType::S32,
        }
    }
}

/// Element types storable in a stub [`Literal`].
pub trait NativeType: Copy {
    fn wrap(data: Vec<Self>) -> LiteralData;
    fn unwrap(data: &LiteralData) -> Option<Vec<Self>>;
}

impl NativeType for f32 {
    fn wrap(data: Vec<f32>) -> LiteralData {
        LiteralData::F32(data)
    }
    fn unwrap(data: &LiteralData) -> Option<Vec<f32>> {
        match data {
            LiteralData::F32(v) => Some(v.clone()),
            _ => None,
        }
    }
}

impl NativeType for i32 {
    fn wrap(data: Vec<i32>) -> LiteralData {
        LiteralData::I32(data)
    }
    fn unwrap(data: &LiteralData) -> Option<Vec<i32>> {
        match data {
            LiteralData::I32(v) => Some(v.clone()),
            _ => None,
        }
    }
}

/// Shape of an array literal: dimensions + element type.
#[derive(Debug, Clone, PartialEq)]
pub struct ArrayShape {
    dims: Vec<i64>,
    ty: ElementType,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    pub fn ty(&self) -> ElementType {
        self.ty
    }
}

/// Host literal: a dense array (f32/i32) or a tuple of literals.
#[derive(Debug, Clone, PartialEq)]
pub enum Literal {
    Array { dims: Vec<i64>, data: LiteralData },
    Tuple(Vec<Literal>),
}

impl Literal {
    /// Rank-1 literal from a host slice.
    pub fn vec1<T: NativeType>(data: &[T]) -> Literal {
        Literal::Array { dims: vec![data.len() as i64], data: T::wrap(data.to_vec()) }
    }

    /// Tuple literal (what executables return with `return_tuple=True`).
    pub fn tuple(parts: Vec<Literal>) -> Literal {
        Literal::Tuple(parts)
    }

    /// Reshape to `dims` (element count must match).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        match self {
            Literal::Array { data, .. } => {
                let want: i64 = dims.iter().product();
                if want as usize != data.len() {
                    return Err(Error::new(format!(
                        "reshape: {} elements into shape {:?}",
                        data.len(),
                        dims
                    )));
                }
                Ok(Literal::Array { dims: dims.to_vec(), data: data.clone() })
            }
            Literal::Tuple(_) => Err(Error::new("cannot reshape a tuple literal")),
        }
    }

    /// Shape of an array literal (errors on tuples).
    pub fn array_shape(&self) -> Result<ArrayShape> {
        match self {
            Literal::Array { dims, data } => {
                Ok(ArrayShape { dims: dims.clone(), ty: data.ty() })
            }
            Literal::Tuple(_) => Err(Error::new("array_shape of a tuple literal")),
        }
    }

    /// Unpack a tuple literal into its parts (errors on arrays).
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        match self {
            Literal::Tuple(parts) => Ok(parts),
            Literal::Array { .. } => Err(Error::new("to_tuple of an array literal")),
        }
    }

    /// Copy the elements out as `Vec<T>` (errors on dtype mismatch/tuples).
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        match self {
            Literal::Array { data, .. } => T::unwrap(data)
                .ok_or_else(|| Error::new(format!("to_vec: literal holds {:?}", data.ty()))),
            Literal::Tuple(_) => Err(Error::new("to_vec of a tuple literal")),
        }
    }
}

/// Parsed HLO module (stub: construction always fails — no parser linked).
#[derive(Debug, Clone)]
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(Error::new(UNAVAILABLE))
    }
}

/// XLA computation handle (stub).
#[derive(Debug, Clone)]
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

/// Device buffer returned by an execution (stub).
#[derive(Debug, Clone)]
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::new(UNAVAILABLE))
    }
}

/// Compiled executable (stub).
#[derive(Debug, Clone)]
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::new(UNAVAILABLE))
    }
}

/// PJRT client (stub: creation reports unavailability).
#[derive(Debug)]
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(Error::new(UNAVAILABLE))
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::new(UNAVAILABLE))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]).reshape(&[2, 2]).unwrap();
        let s = l.array_shape().unwrap();
        assert_eq!(s.dims(), &[2, 2]);
        assert_eq!(s.ty(), ElementType::F32);
        assert_eq!(l.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(l.to_vec::<i32>().is_err());
    }

    #[test]
    fn scalar_reshape() {
        let l = Literal::vec1(&[5i32]).reshape(&[]).unwrap();
        assert_eq!(l.array_shape().unwrap().dims(), &[] as &[i64]);
        assert_eq!(l.to_vec::<i32>().unwrap(), vec![5]);
    }

    #[test]
    fn bad_reshape_errors() {
        assert!(Literal::vec1(&[1.0f32, 2.0]).reshape(&[3]).is_err());
    }

    #[test]
    fn tuples() {
        let t = Literal::tuple(vec![Literal::vec1(&[1.0f32]), Literal::vec1(&[2i32])]);
        let parts = t.clone().to_tuple().unwrap();
        assert_eq!(parts.len(), 2);
        assert!(t.array_shape().is_err());
    }

    #[test]
    fn client_unavailable() {
        let e = PjRtClient::cpu().unwrap_err();
        assert!(e.to_string().contains("unavailable"));
    }
}
