//! Minimal, dependency-free stand-in for the `anyhow` crate.
//!
//! The build environment is offline, so this vendored crate provides exactly
//! the subset of the anyhow API the `hecate` crate uses: [`Error`],
//! [`Result`], and the [`anyhow!`], [`bail!`], [`ensure!`] macros. Like the
//! real crate, `Error` deliberately does **not** implement
//! `std::error::Error` so that the blanket `From<E: std::error::Error>`
//! conversion (what makes `?` work on foreign error types) does not overlap
//! with `From<Error> for Error`.

use std::fmt;

/// A type-erased error: a display message plus the rendered source chain.
pub struct Error {
    msg: String,
}

impl Error {
    /// Construct from any displayable message.
    pub fn msg<M: fmt::Display>(m: M) -> Error {
        Error { msg: m.to_string() }
    }

    /// Attach context, mirroring `anyhow::Error::context`.
    pub fn context<C: fmt::Display>(self, c: C) -> Error {
        Error { msg: format!("{c}: {}", self.msg) }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        // `{:#}` (alternate) prints the same flattened chain.
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        // Render the full source chain eagerly; we only ever display it.
        let mut msg = e.to_string();
        let mut src = e.source();
        while let Some(s) = src {
            msg.push_str(": ");
            msg.push_str(&s.to_string());
            src = s.source();
        }
        Error { msg }
    }
}

/// `Result` with a defaulted error type, as in anyhow.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with an error built from a format string.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::core::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error unless a condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return ::core::result::Result::Err($crate::Error::msg(concat!(
                "condition failed: ",
                stringify!($cond)
            )));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return ::core::result::Result::Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing")
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        let e = inner().unwrap_err();
        assert!(e.to_string().contains("missing"));
    }

    #[test]
    fn macros() {
        fn f(x: i32) -> Result<i32> {
            ensure!(x >= 0, "negative input {x}");
            if x > 10 {
                bail!("too big: {x}");
            }
            Ok(x)
        }
        assert_eq!(f(3).unwrap(), 3);
        assert!(f(-1).unwrap_err().to_string().contains("negative input -1"));
        assert!(f(11).unwrap_err().to_string().contains("too big"));
        let e: Error = anyhow!("code {}", 7);
        assert_eq!(e.to_string(), "code 7");
    }

    #[test]
    fn context_prepends() {
        let e = Error::msg("inner").context("outer");
        assert_eq!(e.to_string(), "outer: inner");
        // alternate formatting used by `main` ({e:#}) must not panic
        assert_eq!(format!("{e:#}"), "outer: inner");
    }
}
